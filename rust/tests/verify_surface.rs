//! Smoke tests for the verify surface itself (ISSUE 10): the golden pin
//! file and the bench baseline are *artifacts* the rest of the repo's
//! claims hang off, so their shape is tested like any other contract.
//!
//! * `BENCH_hotpath.json` (workspace root, written by
//!   `cargo bench --bench hotpath`) must parse with the crate's own JSON
//!   parser and carry the `n_scaling` grid the ROADMAP's perf items
//!   baseline against.
//! * `tests/golden/pins.txt` must be non-empty and cover every
//!   `Scheme` × `ConsensusMode` named in `golden_traces.rs` — a pin file
//!   that silently lost a scheme would let that scheme's numerics drift
//!   unpinned.
//!
//! Neither artifact can be generated without a toolchain, so absence is
//! reported-but-green by default; CI sets `AMB_REQUIRE_PINS=1` in the
//! test legs (which run after the pin regen step) to make pin coverage a
//! hard gate there.

use anytime_mb::util::json::Json;
use anytime_mb::{ConsensusMode, Scheme};

const PINS_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/pins.txt");
const TRACES_SRC: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_traces.rs");
const BENCH_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");

fn require(var: &str) -> bool {
    std::env::var(var).map(|v| v == "1").unwrap_or(false)
}

#[test]
fn bench_baseline_parses_with_n_scaling_grid() {
    let text = match std::fs::read_to_string(BENCH_PATH) {
        Ok(t) => t,
        Err(_) => {
            assert!(
                !require("AMB_REQUIRE_BENCH"),
                "AMB_REQUIRE_BENCH=1 but {BENCH_PATH} is missing — run \
                 `cargo bench --bench hotpath` first"
            );
            eprintln!(
                "verify_surface: no {BENCH_PATH}; run `cargo bench --bench hotpath` to \
                 commit the first baseline (ROADMAP Open item 0)"
            );
            return;
        }
    };
    let doc = Json::parse(&text).expect("BENCH_hotpath.json must parse");
    assert_eq!(doc.path("bench").and_then(Json::as_str), Some("hotpath"));

    let results = doc.path("results").and_then(Json::as_arr).expect("results array");
    assert!(!results.is_empty(), "bench baseline has no timed rows");
    for row in results {
        assert!(row.path("name").and_then(Json::as_str).is_some(), "row missing name");
        let mean = row.path("mean_s").and_then(Json::as_f64).expect("row missing mean_s");
        assert!(mean.is_finite() && mean >= 0.0, "non-finite mean_s");
    }

    // The n-scaling grid: every row carries the CSR footprint and kernel
    // timings, and the grid spans more than one n (otherwise it is a
    // point, not a scaling baseline).
    let nscale = doc.path("n_scaling").and_then(Json::as_arr).expect("n_scaling array");
    assert!(!nscale.is_empty(), "n_scaling grid is empty");
    let mut ns = Vec::new();
    for row in nscale {
        let n = row.path("n").and_then(Json::as_usize).expect("n_scaling row missing n");
        let nnz = row.path("nnz").and_then(Json::as_usize).expect("missing nnz");
        assert!(n >= 1 && nnz >= 1, "degenerate n_scaling row");
        for key in ["csr_build_s", "sparse_mix5_s"] {
            let t = row.path(key).and_then(Json::as_f64);
            assert!(t.is_some_and(|t| t.is_finite() && t >= 0.0), "bad {key}");
        }
        ns.push(n);
    }
    ns.sort_unstable();
    ns.dedup();
    assert!(ns.len() >= 2, "n_scaling grid covers only n={ns:?} — not a scaling axis");
}

/// The scheme labels the pin grid must carry, built from the library's
/// own `Scheme::name()` so a rename updates this test automatically.
fn expected_scheme_labels() -> Vec<&'static str> {
    vec![
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 }.name(),
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 }.name(),
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: false }.name(),
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true }.name(),
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 }.name(),
    ]
}

/// Mode-label *prefixes* (the pin format appends parameters: `gossip5`,
/// `jitter5±2`, `hier3-4-3`), keyed by the `ConsensusMode` variant ident
/// as it appears in golden_traces.rs source.
fn mode_prefixes() -> Vec<(&'static str, &'static str)> {
    // Constructed once so the variants stay type-checked against the
    // library — a removed variant breaks this test at compile time.
    let _grid = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
        ConsensusMode::Hierarchical { shards: 3, intra_rounds: 4, inter_rounds: 3 },
    ];
    vec![
        ("Exact", "exact"),
        ("Gossip", "gossip"),
        ("GossipJitter", "jitter"),
        ("Hierarchical", "hier"),
    ]
}

#[test]
fn golden_pins_cover_every_scheme_and_mode_named_in_golden_traces() {
    let pins = match std::fs::read_to_string(PINS_PATH) {
        Ok(t) => t,
        Err(_) => {
            assert!(
                !require("AMB_REQUIRE_PINS"),
                "AMB_REQUIRE_PINS=1 but {PINS_PATH} is missing — the regen step must \
                 run before the test legs (see .github/workflows/ci.yml)"
            );
            eprintln!(
                "verify_surface: no {PINS_PATH}; generate with `cargo test --test \
                 golden_traces regen_golden_pins -- --ignored` (ROADMAP Open item 0)"
            );
            return;
        }
    };
    let lines: Vec<&str> =
        pins.lines().filter(|l| !l.is_empty() && !l.starts_with('#')).collect();
    assert!(!lines.is_empty(), "pins.txt exists but pins no traces");

    // Structural sanity: every pin line is `<scheme> d=<D> × <mode>: …`.
    for line in &lines {
        let (label, content) = line.split_once(": ").expect("pin line has `label: content`");
        assert!(label.contains(" × "), "pin label `{label}` missing the scheme × mode split");
        assert!(content.starts_with("batches="), "pin content for `{label}` lost its shape");
    }

    // Coverage is driven by what golden_traces.rs NAMES, read from its
    // source: a variant dropped from the grid there must fail here, not
    // silently shrink the pinned surface.
    let src = std::fs::read_to_string(TRACES_SRC).expect("golden_traces.rs is a sibling test");
    let named = |needle: &str| src.contains(needle);

    let labels: Vec<&str> =
        lines.iter().map(|l| l.split_once(": ").expect("checked above").0).collect();
    let grid_modes = ["exact", "gossip", "jitter"];
    for scheme in expected_scheme_labels() {
        for mode in grid_modes {
            let hit = labels
                .iter()
                .any(|l| l.starts_with(&format!("{scheme} ")) && l.contains(mode));
            assert!(hit, "pins.txt has no trace for {scheme} × {mode}*");
        }
    }
    for (variant, prefix) in mode_prefixes() {
        if !named(&format!("ConsensusMode::{variant}")) {
            continue;
        }
        assert!(
            labels.iter().any(|l| l.contains(prefix)),
            "ConsensusMode::{variant} is named in golden_traces.rs but no pin label \
             contains `{prefix}`"
        );
    }
    // The fabric pins (ideal + constrained) ride outside the grid.
    for fabric in ["ideal-fabric", "fabric"] {
        assert!(
            labels.iter().any(|l| l.contains(fabric)),
            "pins.txt lost the network-fabric pin `{fabric}`"
        );
    }
}
