//! Property-based integration tests over the coordinator's invariants
//! (DESIGN.md §6), using the in-tree `prop` harness and the unified
//! `RunSpec` → `anytime_mb::run` API.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::prop::{forall, Gen};
use anytime_mb::straggler::{Deterministic, ShiftedExp, StragglerModel};
use anytime_mb::topology::Topology;
use anytime_mb::{prop_assert, prop_assert_close};
use anytime_mb::{ConsensusMode, RunOutput, RunSpec, SimRuntime};

fn setup(g: &mut Gen) -> (Arc<DataSource>, DualAveraging, Topology) {
    let d = g.usize_in(4, 48);
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, g.u64())));
    let opt = DualAveraging::new(
        BetaSchedule::new(g.f64_in(0.5, 2.0), g.f64_in(50.0, 2000.0)),
        4.0 * (d as f64).sqrt(),
    );
    let n = g.usize_in(3, 12);
    let topo = Topology::erdos_connected(n, g.f64_in(0.2, 0.8), g.u64());
    (src, opt, topo)
}

fn sim_run(
    spec: &RunSpec,
    topo: &Topology,
    strag: &dyn StragglerModel,
    src: &Arc<DataSource>,
    opt: &DualAveraging,
) -> RunOutput {
    let s = src.clone();
    let o = opt.clone();
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(s.clone(), o.clone()))
    };
    anytime_mb::run(&SimRuntime::new(strag), spec, topo, &mk, src.f_star()).unwrap()
}

/// AMB epoch wall time is exactly (T + T_c)·τ for ANY straggler draw,
/// topology, or consensus budget — the defining property.
#[test]
fn prop_amb_wall_time_deterministic() {
    forall(15, 0x9_001, |g| {
        let (src, opt, topo) = setup(g);
        let strag = ShiftedExp {
            zeta: g.f64_in(0.1, 2.0),
            lambda: g.f64_in(0.3, 3.0),
            unit_batch: g.usize_in(20, 200),
        };
        let t = g.f64_in(0.5, 5.0);
        let tc = g.f64_in(0.1, 2.0);
        let epochs = g.usize_in(2, 8);
        let spec = RunSpec::amb("amb", t, tc, g.usize_in(1, 10), epochs, g.u64());
        let rec = sim_run(&spec, &topo, &strag, &src, &opt).record;
        prop_assert_close!(rec.total_time(), epochs as f64 * (t + tc), 1e-9);
        Ok(())
    });
}

/// FMB epoch time equals the slowest node's completion time (plus T_c);
/// with a deterministic model it's exactly unit_time·(b/unit)·τ + τ·T_c.
#[test]
fn prop_fmb_wall_time_max_gated() {
    forall(15, 0x9_002, |g| {
        let (src, opt, topo) = setup(g);
        let unit_time = g.f64_in(0.5, 3.0);
        let unit = g.usize_in(10, 100);
        let strag = Deterministic { unit_time, unit_batch: unit };
        let tc = g.f64_in(0.1, 1.0);
        let epochs = g.usize_in(2, 6);
        let b = g.usize_in(5, 150);
        let spec = RunSpec::fmb("fmb", b, tc, 3, epochs, g.u64());
        let rec = sim_run(&spec, &topo, &strag, &src, &opt).record;
        let per_epoch = unit_time * b as f64 / unit as f64 + tc;
        prop_assert_close!(rec.total_time(), epochs as f64 * per_epoch, 1e-9);
        Ok(())
    });
}

/// Global batch accounting: b(t) == Σ_i b_i(t) and (AMB, linear progress)
/// each b_i == floor(T / sec_per_grad) — all nodes within the min/max
/// recorded bounds, and b(t) between n·min and n·max.
#[test]
fn prop_batch_accounting_consistent() {
    forall(15, 0x9_003, |g| {
        let (src, opt, topo) = setup(g);
        let n = topo.n();
        let strag = ShiftedExp {
            zeta: g.f64_in(0.2, 1.0),
            lambda: g.f64_in(0.5, 2.0),
            unit_batch: g.usize_in(20, 100),
        };
        let spec = RunSpec::amb("amb", g.f64_in(1.0, 4.0), 0.5, 3, 5, g.u64());
        let rec = sim_run(&spec, &topo, &strag, &src, &opt).record;
        for e in &rec.epochs {
            prop_assert!(e.min_node_batch <= e.max_node_batch);
            prop_assert!(e.batch >= n * e.min_node_batch);
            prop_assert!(e.batch <= n * e.max_node_batch);
            prop_assert!(e.potential >= e.batch, "c(t) >= b(t) (undone work)");
        }
        Ok(())
    });
}

/// Consensus-error monotonicity in rounds, measured end-to-end through
/// the coordinator (not just the consensus unit).
#[test]
fn prop_more_rounds_not_worse() {
    forall(8, 0x9_004, |g| {
        let (src, opt, topo) = setup(g);
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 50 };
        let seed = g.u64();
        let mut err_at = |rounds: usize| -> f64 {
            let spec = RunSpec::amb("amb", 2.0, 0.5, rounds, 4, seed);
            let rec = sim_run(&spec, &topo, &strag, &src, &opt).record;
            rec.epochs.iter().map(|e| e.consensus_err).sum::<f64>()
        };
        let few = err_at(1);
        let many = err_at(12);
        prop_assert!(many <= few * 1.05, "rounds 1: {few}, rounds 12: {many}");
        Ok(())
    });
}

/// Exact-consensus runs are invariant to the communication topology.
#[test]
fn prop_exact_consensus_topology_invariant() {
    forall(8, 0x9_005, |g| {
        let d = g.usize_in(4, 32);
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, g.u64())));
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * (d as f64).sqrt());
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 50 };
        let seed = g.u64();
        let run_on = |topo: &Topology| {
            let spec = RunSpec::amb("amb", 2.0, 0.5, 1, 4, seed)
                .with_consensus(ConsensusMode::Exact);
            sim_run(&spec, topo, &strag, &src, &opt)
        };
        let a = run_on(&Topology::ring(6));
        let b = run_on(&Topology::complete(6));
        for (wa, wb) in a.final_w.rows().zip(b.final_w.rows()) {
            for k in 0..wa.len() {
                prop_assert_close!(wa[k], wb[k], 1e-5);
            }
        }
        Ok(())
    });
}

/// Bit-level reproducibility across repeated runs with the same seed.
#[test]
fn prop_seeded_reproducibility() {
    forall(6, 0x9_006, |g| {
        let (src, opt, topo) = setup(g);
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.5, unit_batch: 60 };
        let seed = g.u64();
        let run = || {
            let spec = RunSpec::amb("amb", 1.5, 0.4, 4, 5, seed);
            sim_run(&spec, &topo, &strag, &src, &opt)
        };
        let a = run();
        let b = run();
        for (ea, eb) in a.record.epochs.iter().zip(&b.record.epochs) {
            prop_assert!(ea.batch == eb.batch);
            prop_assert!(ea.loss.to_bits() == eb.loss.to_bits());
        }
        prop_assert!(a.final_w == b.final_w);
        Ok(())
    });
}
