//! Elastic-membership (churn) invariants across the whole stack:
//!
//! * an all-active schedule (`IidDropout { p: 0 }`) reproduces the
//!   static-membership run **bit-for-bit** on the simulator;
//! * the ISSUE-4 acceptance run — ring-10, 20% i.i.d. dropout, AMB vs
//!   FMB — completes on BOTH runtimes with membership-consistent batch
//!   accounting;
//! * sim ↔ threaded parity holds under churn (FMB + Exact consensus:
//!   exactly equal batches, losses within f32-reorder tolerance);
//! * a node absent for an epoch holds its primal state bit-for-bit
//!   (rejoin semantics).

use std::sync::Arc;

use anytime_mb::churn::{ChurnSchedule, ChurnSpec};
use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::{Deterministic, ShiftedExp};
use anytime_mb::topology::Topology;
use anytime_mb::{ConsensusMode, RunOutput, RunSpec, Runtime, SimRuntime, ThreadedRuntime};

fn linreg_factory(
    d: usize,
    seed: u64,
) -> (
    impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
    Option<f64>,
) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * (d as f64).sqrt());
    let f_star = src.f_star();
    (
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        },
        f_star,
    )
}

fn assert_bitwise_equal(a: &RunOutput, b: &RunOutput, label: &str) {
    assert_eq!(a.record.epochs.len(), b.record.epochs.len(), "{label}: epoch count");
    for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
        assert_eq!(x.batch, y.batch, "{label}: batch @ epoch {}", x.epoch);
        assert_eq!(x.potential, y.potential, "{label}: potential @ epoch {}", x.epoch);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{label}: loss bits @ epoch {}", x.epoch);
        assert_eq!(x.error.to_bits(), y.error.to_bits(), "{label}: error bits @ epoch {}", x.epoch);
        assert_eq!(
            x.consensus_err.to_bits(),
            y.consensus_err.to_bits(),
            "{label}: consensus_err bits @ epoch {}",
            x.epoch
        );
    }
    assert_eq!(a.rounds, b.rounds, "{label}: rounds log");
    for (k, (x, y)) in a.final_w.as_slice().iter().zip(b.final_w.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: final_w[{k}]");
    }
}

fn sim_run(spec: &RunSpec, topo: &Topology) -> RunOutput {
    let (mk, f_star) = linreg_factory(24, 5);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    SimRuntime::new(&strag).run(spec, topo, &mk, f_star).unwrap()
}

/// A schedule that never drops a node must reproduce TODAY's outputs
/// bit-for-bit, for every scheme × consensus mode: every epoch takes the
/// zero-rebuild base-matrix path and the static update mask.
#[test]
fn all_active_schedule_reproduces_static_run_bitwise() {
    use anytime_mb::Scheme;
    let topo = Topology::paper_fig2();
    let schemes = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true },
    ];
    let modes = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ];
    for scheme in schemes {
        for mode in modes {
            let base = RunSpec::new(scheme.name(), scheme, 5, 13).with_consensus(mode);
            let churned = base
                .clone()
                .with_churn(ChurnSpec::IidDropout { p: 0.0, seed: 77 });
            let a = sim_run(&base, &topo);
            let b = sim_run(&churned, &topo);
            assert_bitwise_equal(&a, &b, &format!("{} × {mode:?}", scheme.name()));
            assert_eq!(b.active_counts, vec![10; 5]);
        }
    }
}

/// ISSUE-4 acceptance: ring-10, 20% i.i.d. dropout, AMB vs FMB on BOTH
/// runtimes — runs complete, batch accounting matches the membership
/// table, and the sim run is bit-reproducible.
#[test]
fn acceptance_ring10_dropout20_amb_vs_fmb_both_runtimes() {
    let topo = Topology::ring(10);
    let epochs = 6;
    let churn = ChurnSpec::IidDropout { p: 0.2, seed: 42 };
    let schedule = ChurnSchedule::new(&churn, 10, epochs);
    let expected_counts: Vec<usize> = (1..=epochs).map(|t| schedule.active_count(t)).collect();

    // Deterministic unit times so FMB batch accounting is exact on both
    // runtimes and compute windows are fast real-time.
    let strag = Deterministic { unit_time: 0.02, unit_batch: 32 };
    let (mk, f_star) = linreg_factory(16, 3);

    let amb_spec = RunSpec::amb("accept-amb", 0.04, 0.03, 3, epochs, 9)
        .with_grad_chunk(8)
        .with_churn(churn.clone());
    let fmb_spec = RunSpec::fmb("accept-fmb", 32, 0.03, 3, epochs, 9)
        .with_grad_chunk(8)
        .with_churn(churn.clone());

    for spec in [&amb_spec, &fmb_spec] {
        let sim = SimRuntime::new(&strag).run(spec, &topo, &mk, f_star).unwrap();
        let thr = ThreadedRuntime.run(spec, &topo, &mk, f_star).unwrap();
        for out in [&sim, &thr] {
            assert_eq!(out.record.epochs.len(), epochs, "{} lost epochs", spec.name);
            assert_eq!(out.active_counts, expected_counts, "{} membership", spec.name);
        }
        // FMB: batch = |A(t)| × quota EXACTLY on both runtimes.
        if spec.name.contains("fmb") {
            for (e, (es, et)) in sim.record.epochs.iter().zip(&thr.record.epochs).enumerate() {
                let want = expected_counts[e] * 32;
                assert_eq!(es.batch, want, "sim fmb epoch {}", e + 1);
                assert_eq!(et.batch, want, "threaded fmb epoch {}", e + 1);
            }
        }
        // sim runs are bit-reproducible under churn
        let sim2 = SimRuntime::new(&strag).run(spec, &topo, &mk, f_star).unwrap();
        assert_bitwise_equal(&sim, &sim2, &format!("{} repro", spec.name));
    }
}

/// Sim ↔ threaded parity under churn: FMB + Exact consensus + a
/// deterministic straggler give exactly equal batches and losses within
/// f32-chunked-summation tolerance — the runtime-parity contract
/// extended to elastic membership.
#[test]
fn fmb_exact_parity_across_runtimes_under_churn() {
    let topo = Topology::ring(4);
    let (mk, f_star) = linreg_factory(16, 2);
    let churn = ChurnSpec::Trace {
        active: vec![vec![true], vec![true, false, true], vec![true], vec![true, true, false]],
    };
    let spec = RunSpec::fmb("churn-parity", 48, 0.05, 1, 6, 21)
        .with_consensus(ConsensusMode::Exact)
        .with_grad_chunk(16)
        .with_churn(churn);
    let strag = Deterministic { unit_time: 0.01, unit_batch: 48 };

    let sim = SimRuntime::new(&strag).run(&spec, &topo, &mk, f_star).unwrap();
    let thr = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();

    assert_eq!(sim.active_counts, thr.active_counts);
    for (es, et) in sim.record.epochs.iter().zip(&thr.record.epochs) {
        assert_eq!(es.batch, et.batch, "epoch {}", es.epoch);
        assert_eq!(es.min_node_batch, et.min_node_batch);
        assert_eq!(es.max_node_batch, et.max_node_batch);
        let rel = (es.loss - et.loss).abs() / es.loss.abs().max(et.loss.abs()).max(1e-12);
        assert!(rel < 1e-2, "epoch {}: sim loss {} vs threaded {}", es.epoch, es.loss, et.loss);
    }
    // per-node primals agree across runtimes (same data streams, same
    // active-set averaging in f64 node order)
    for (i, (ws, wt)) in sim.final_w.rows().zip(thr.final_w.rows()).enumerate() {
        let mut diff = 0.0f64;
        let mut norm = 0.0f64;
        for k in 0..ws.len() {
            diff += ((ws[k] - wt[k]) as f64).powi(2);
            norm += (ws[k] as f64).powi(2);
        }
        assert!(
            diff.sqrt() < 2e-2 * norm.sqrt().max(1e-9),
            "node {i} final w rel diff {}",
            diff.sqrt() / norm.sqrt().max(1e-9)
        );
    }
}

/// Rejoin semantics: a node absent from the FINAL epoch ends the run
/// with exactly the primal it held after the previous epoch — absence
/// is a bitwise freeze, not an approximate one.
#[test]
fn absent_node_holds_primal_bitwise() {
    let topo = Topology::complete(4);
    // node 0 present only in epoch 1 of 2
    let churn = ChurnSpec::Trace {
        active: vec![vec![true, false], vec![true], vec![true], vec![true]],
    };
    let long = RunSpec::amb("hold-2", 2.0, 0.5, 4, 2, 17).with_churn(churn);
    let short = RunSpec::amb("hold-1", 2.0, 0.5, 4, 1, 17);
    let a = sim_run(&long, &topo);
    let b = sim_run(&short, &topo);
    // node 0's primal after epoch 2 (absent) == after epoch 1 (present)
    for (x, y) in a.final_w.row(0).iter().zip(b.final_w.row(0)) {
        assert_eq!(x.to_bits(), y.to_bits(), "absent node's primal drifted");
    }
    // the others kept updating
    assert_ne!(a.final_w.row(1), b.final_w.row(1));
}

/// Churn composes with every consensus mode and scheme on the simulator
/// (GossipJitter exercises run_per_node over induced matrices; backup
/// exercises the active-set survivor accounting).
#[test]
fn churn_composes_with_schemes_and_modes() {
    use anytime_mb::Scheme;
    let topo = Topology::paper_fig2();
    let churn = ChurnSpec::Markov { p_down: 0.2, p_up: 0.5, seed: 23 };
    let schemes = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: false },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true },
    ];
    let modes = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 4 },
        ConsensusMode::GossipJitter { mean: 4, jitter: 2 },
    ];
    let schedule = ChurnSchedule::new(&churn, 10, 6);
    for scheme in schemes {
        for mode in modes {
            let spec = RunSpec::new(scheme.name(), scheme, 6, 31)
                .with_consensus(mode)
                .with_churn(churn.clone());
            let out = sim_run(&spec, &topo);
            assert_eq!(out.record.epochs.len(), 6);
            for t in 1..=6 {
                assert_eq!(out.active_counts[t - 1], schedule.active_count(t));
                // absent nodes never gossip
                for i in 0..10 {
                    if !schedule.active(t)[i] {
                        assert_eq!(out.rounds[i][t - 1], 0, "absent node {i} gossiped @ {t}");
                    }
                }
            }
            let last = out.record.epochs.last().unwrap();
            assert!(
                last.error.is_finite(),
                "{} × {mode:?}: error diverged",
                scheme.name()
            );
        }
    }
}
