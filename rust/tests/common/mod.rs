//! Shared integration-test helpers (pulled in via `mod common;` — the
//! `common/mod.rs` form keeps cargo from treating this as a test
//! target of its own).

use anytime_mb::RunOutput;

/// Bitwise comparison of everything a [`RunOutput`] records — the
/// determinism-contract assertion used by `tests/parallel_determinism.rs`
/// (threads=1 ≡ threads=k) and `tests/amb_dg.rs` (`AmbDg { delay: 0 }`
/// ≡ `Amb`).  One copy, so a new `EpochStats` field cannot be compared
/// in one suite and silently skipped in the other.
pub fn assert_bitwise_equal(a: &RunOutput, b: &RunOutput, label: &str) {
    assert_eq!(a.record.epochs.len(), b.record.epochs.len(), "{label}: epoch count");
    for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
        assert_eq!(x.batch, y.batch, "{label}: batch @ epoch {}", x.epoch);
        assert_eq!(x.potential, y.potential, "{label}: potential @ epoch {}", x.epoch);
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{label}: loss bits @ epoch {} ({} vs {})",
            x.epoch,
            x.loss,
            y.loss
        );
        assert_eq!(
            x.error.to_bits(),
            y.error.to_bits(),
            "{label}: error bits @ epoch {} ({} vs {})",
            x.epoch,
            x.error,
            y.error
        );
        assert_eq!(
            x.consensus_err.to_bits(),
            y.consensus_err.to_bits(),
            "{label}: consensus_err bits @ epoch {}",
            x.epoch
        );
        assert_eq!(
            x.wall_time.to_bits(),
            y.wall_time.to_bits(),
            "{label}: wall_time bits @ epoch {}",
            x.epoch
        );
        assert_eq!(
            x.max_staleness, y.max_staleness,
            "{label}: max_staleness @ epoch {}",
            x.epoch
        );
        assert_eq!(
            x.mean_staleness.to_bits(),
            y.mean_staleness.to_bits(),
            "{label}: mean_staleness bits @ epoch {}",
            x.epoch
        );
        assert_eq!(
            x.conservation_drift.to_bits(),
            y.conservation_drift.to_bits(),
            "{label}: conservation_drift bits @ epoch {}",
            x.epoch
        );
    }
    assert_eq!(a.rounds, b.rounds, "{label}: per-(node, epoch) gossip rounds");
    assert_eq!(a.active_counts, b.active_counts, "{label}: active counts");
    assert_eq!(a.final_w.n(), b.final_w.n(), "{label}: final_w rows");
    for (k, (x, y)) in a
        .final_w
        .as_slice()
        .iter()
        .zip(b.final_w.as_slice())
        .enumerate()
    {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: final_w[{k}] ({x} vs {y})");
    }
}
