//! Integration: the real-time threaded cluster (one thread per node,
//! channel network) running the full AMB protocol through the unified
//! `RunSpec` → `anytime_mb::run` API.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::topology::Topology;
use anytime_mb::coordinator::GOSSIP_UNTIL_DEADLINE;
use anytime_mb::{RunSpec, ThreadedRuntime};

fn spec(epochs: usize, t_compute: f64, t_consensus: f64, slowdown: Vec<f64>) -> RunSpec {
    RunSpec::amb("amb-threaded", t_compute, t_consensus, GOSSIP_UNTIL_DEADLINE, epochs, 9)
        .with_grad_chunk(16)
        .with_slowdown(slowdown)
        .with_node_log()
}

fn linreg_factory(
    d: usize,
    seed: u64,
) -> (
    impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
    Option<f64>,
) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * (d as f64).sqrt());
    let f_star = src.f_star();
    (
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        },
        f_star,
    )
}

#[test]
fn five_node_ring_trains() {
    let topo = Topology::ring(5);
    let (mk, f_star) = linreg_factory(24, 3);
    let out = anytime_mb::run(&ThreadedRuntime, &spec(8, 0.05, 0.04, vec![]), &topo, &mk, f_star)
        .unwrap();
    assert_eq!(out.record.epochs.len(), 8);
    let first = out.record.epochs[0].error;
    let last = out.record.epochs.last().unwrap().error;
    assert!(last < first, "no progress {first} -> {last}");
    // consensus rounds were completed by every node in most epochs
    let zero_round_epochs: usize = out
        .rounds
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&r| r == 0)
        .count();
    let total: usize = out.rounds.iter().map(|r| r.len()).sum();
    assert!(
        zero_round_epochs * 4 < total,
        "too many zero-round node-epochs: {zero_round_epochs}/{total}"
    );
}

#[test]
fn epoch_wall_time_is_fixed_regardless_of_stragglers() {
    // The defining AMB property, now in real time: epoch boundaries land
    // on the absolute schedule even with a 4x-slowed node.
    let topo = Topology::ring(4);
    let (mk, f_star) = linreg_factory(16, 5);
    let s = spec(6, 0.05, 0.03, vec![4.0, 1.0, 1.0, 1.0]);
    let t0 = std::time::Instant::now();
    let out = anytime_mb::run(&ThreadedRuntime, &s, &topo, &mk, f_star).unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    let scheduled = 6.0 * (0.05 + 0.03);
    assert!(
        elapsed < scheduled * 1.8 + 0.5,
        "cluster overran the fixed schedule: {elapsed}s vs {scheduled}s"
    );
    let log = out.node_log.as_ref().unwrap();
    // the slowed node still contributed work every epoch
    assert!(log.batches[0].iter().all(|&b| b > 0));
    // and contributed less than the fast nodes
    let slow: usize = log.batches[0].iter().sum();
    let fast: usize = log.batches[2].iter().sum();
    assert!(slow < fast, "slow={slow} fast={fast}");
    // the record's wall clock stays in spec units on the absolute schedule
    assert!((out.record.total_time() - scheduled).abs() < 1e-9);
}

#[test]
fn nodes_converge_to_similar_models() {
    // Consensus must keep node models close: the leader's error is low,
    // every node contributed batches, and — now that the unified output
    // exposes every node's primal — the final w's agree across nodes.
    let topo = Topology::complete(4);
    let (mk, f_star) = linreg_factory(16, 7);
    let out =
        anytime_mb::run(&ThreadedRuntime, &spec(10, 0.05, 0.04, vec![]), &topo, &mk, f_star)
            .unwrap();
    let last = out.record.epochs.last().unwrap();
    assert!(last.error < out.record.epochs[0].error * 0.5);
    assert!(last.min_node_batch > 0);
    assert_eq!(out.final_w.n(), 4);
    let norm0: f64 =
        out.final_w.row(0).iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let spread = anytime_mb::metrics::max_primal_spread(&out.final_w);
    // Max pairwise spread dominates any node's distance from node 0, so
    // this bound is at least as strict as the pre-arena test (each node
    // within 0.25·‖w₀‖ of node 0).
    assert!(
        spread < 0.25 * norm0.max(1e-9),
        "node models diverged: spread={spread} norm={norm0}"
    );
}

#[test]
fn single_neighbor_line_topology() {
    // Degenerate connectivity (path graph) still terminates and trains.
    let topo = Topology::from_edges(3, &[(0, 1), (1, 2)]);
    let (mk, f_star) = linreg_factory(8, 11);
    let out = anytime_mb::run(&ThreadedRuntime, &spec(5, 0.04, 0.03, vec![]), &topo, &mk, f_star)
        .unwrap();
    assert_eq!(out.record.epochs.len(), 5);
    assert!(out.record.epochs.iter().all(|e| e.batch > 0));
}
