//! Fault-injection plane invariants across the whole stack (ISSUE 8):
//!
//! * an all-clear `FaultSpec` (every knob zero — a non-default fault
//!   seed and a round timeout alone do not arm anything) reproduces the
//!   no-fault run **bit-for-bit** on the simulator, for every
//!   `Scheme` × `ConsensusMode`, and composed with churn — the same
//!   pins hold at any `AMB_THREADS`, which CI exercises in both legs;
//! * faulty runs are themselves bit-reproducible (the fault plane is a
//!   pure function of (spec, seed, epoch, round, edge));
//! * the ISSUE-8 acceptance run — 5% iid loss, AMB on the fig-5
//!   Erdős–Rényi graph — still reaches the no-fault target error, with
//!   the conservation drift MEASURED (finite, positive somewhere) while
//!   the clean run's drift column is exactly 0.0;
//! * crash/recover: a crashed node loses its state and re-syncs from
//!   the peer average exactly once at rejoin, and crashes alone (no
//!   link faults) never fire a drop — drift stays identically zero;
//! * unsupported combinations come back as clean `Err`s, not panics.

use std::sync::Arc;

mod common;
use common::assert_bitwise_equal;

use anytime_mb::churn::ChurnSpec;
use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::{
    ConsensusMode, CrashWindow, FaultSpec, Flap, RunOutput, RunSpec, Runtime, Scheme, SimRuntime,
};

fn try_sim_run(spec: &RunSpec, topo: &Topology) -> anyhow::Result<RunOutput> {
    let (mk, f_star) = linreg_factory(24, 5);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    SimRuntime::new(&strag).run(spec, topo, &mk, f_star)
}

fn sim_run(spec: &RunSpec, topo: &Topology) -> RunOutput {
    try_sim_run(spec, topo).unwrap()
}

fn linreg_factory(
    d: usize,
    seed: u64,
) -> (
    impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
    Option<f64>,
) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 400.0), 4.0 * (d as f64).sqrt());
    let f_star = src.f_star();
    (
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        },
        f_star,
    )
}

/// An all-clear spec with deliberately non-default inert knobs: the
/// fault seed and the round timeout must not arm the fault plane.
fn all_clear() -> FaultSpec {
    FaultSpec { seed: 99, round_timeout: 0.125, ..FaultSpec::none() }
}

/// ISSUE-8 acceptance anchor: the all-clear spec is bit-for-bit the
/// no-fault run for every scheme × consensus mode that runs on the sim.
#[test]
fn all_clear_faultspec_reproduces_baseline_bitwise_everywhere() {
    let topo = Topology::paper_fig2();
    let schemes = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 },
    ];
    let modes = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
        ConsensusMode::Hierarchical { shards: 2, intra_rounds: 3, inter_rounds: 2 },
    ];
    for scheme in schemes {
        for mode in modes {
            let base = RunSpec::new(scheme.name(), scheme, 5, 13).with_consensus(mode);
            let faulted = base.clone().with_faults(all_clear());
            let a = sim_run(&base, &topo);
            let b = sim_run(&faulted, &topo);
            assert_bitwise_equal(&a, &b, &format!("{} × {mode:?}", scheme.name()));
        }
    }
}

/// ... and composed with churn: membership rebuilds must not read the
/// fault plane when it is all-clear.
#[test]
fn all_clear_faultspec_is_bitwise_under_churn() {
    let topo = Topology::ring(8);
    let churn = ChurnSpec::IidDropout { p: 0.3, seed: 11 };
    let base = RunSpec::amb("churned", 2.0, 0.5, 5, 6, 13).with_churn(churn);
    let faulted = base.clone().with_faults(all_clear());
    let a = sim_run(&base, &topo);
    let b = sim_run(&faulted, &topo);
    assert!(a.active_counts.iter().any(|&c| c < 8), "churn dropped nobody — weak test");
    assert_bitwise_equal(&a, &b, "all-clear × churn");
}

/// The fault plane is deterministic: one faulty spec, two runs, bitwise
/// identical output — including the measured drift column.
#[test]
fn faulty_runs_are_bit_reproducible() {
    let topo = Topology::paper_fig2();
    let faults = FaultSpec {
        loss: 0.1,
        flap: Some(Flap { p_down: 0.1, p_up: 0.5 }),
        crashes: vec![CrashWindow { node: 2, from: 3, to: 4 }],
        seed: 21,
        ..FaultSpec::none()
    };
    let spec = RunSpec::amb("faulty-repro", 2.0, 0.5, 5, 6, 13).with_faults(faults);
    let a = sim_run(&spec, &topo);
    let b = sim_run(&spec, &topo);
    assert_bitwise_equal(&a, &b, "faulty repeat run");
    // and the faults actually bit: some epoch measured nonzero drift
    assert!(
        a.record.epochs.iter().any(|e| e.conservation_drift > 0.0),
        "loss 0.1 + flaps fired no drops — weak test"
    );
}

/// ISSUE-8 acceptance: 5% iid loss on the fig-5 topology still reaches
/// the no-fault run's target error, and the mean-conservation drift is
/// measured rather than assumed away.
#[test]
fn five_percent_loss_on_fig5_reaches_target_with_measured_drift() {
    let topo = Topology::erdos_connected(20, 0.2, 7);
    let clean_spec = RunSpec::amb("fig5-clean", 2.5, 0.5, 5, 12, 7);
    let lossy_spec = clean_spec
        .clone()
        .with_faults(FaultSpec { loss: 0.05, seed: 77, ..FaultSpec::none() });
    let clean = sim_run(&clean_spec, &topo);
    let lossy = sim_run(&lossy_spec, &topo);

    // the no-drop run's drift column is exactly zero
    assert!(clean.record.epochs.iter().all(|e| e.conservation_drift == 0.0));
    // the lossy run measures finite drift and fires somewhere
    assert!(lossy.record.epochs.iter().all(|e| e.conservation_drift.is_finite()));
    assert!(
        lossy.record.epochs.iter().any(|e| e.conservation_drift > 0.0),
        "5% loss over 5 rounds × ~80 directed edges fired nothing"
    );

    let target = clean.record.epochs.last().unwrap().error * 1.5;
    assert!(
        lossy.record.time_to_error(target).is_some(),
        "lossy run never reached target {target:e}; final error {:e}",
        lossy.record.epochs.last().unwrap().error
    );
}

/// Crash ≠ churn: the dead node's state is LOST at onset and rebuilt
/// from the peer average exactly once at rejoin (compute suppressed for
/// that one epoch), and crashes alone never fire link drops.
#[test]
fn crash_rejoin_resyncs_from_peers_exactly_once() {
    let topo = Topology::ring(4);
    let faults = FaultSpec {
        crashes: vec![CrashWindow { node: 1, from: 2, to: 3 }],
        ..FaultSpec::none()
    };
    let spec = RunSpec::amb("crash-integ", 2.0, 0.5, 5, 6, 5)
        .with_node_log()
        .with_faults(faults);
    let out = sim_run(&spec, &topo);

    assert_eq!(out.active_counts, vec![4, 3, 3, 4, 4, 4]);
    let log = out.node_log.as_ref().unwrap();
    // dead epochs 2–3 AND the rejoin epoch 4 compute nothing (the
    // rejoin epoch is the one-shot peer re-sync); epochs 5–6 resume
    assert_eq!(&log.batches[1][1..=3], &[0, 0, 0], "crash window must suppress compute");
    assert!(log.batches[1][4] > 0, "node 1 never resumed computing");
    // dead node gossips no rounds; the rejoining node participates
    assert_eq!(&out.rounds[1][1..=2], &[0, 0], "dead node gossiped");
    assert!(out.rounds[1][3] > 0, "rejoining node must join consensus for the re-sync");
    // crashes alone fire no drops: drift identically zero
    assert!(out.record.epochs.iter().all(|e| e.conservation_drift == 0.0));
}

/// Satellite 2: unsupported mode combinations and invalid specs are
/// surfaced as clean errors, not panics.
#[test]
fn unsupported_combinations_error_cleanly() {
    let topo = Topology::ring(4);
    let reject = |spec: RunSpec, needle: &str| {
        let err = try_sim_run(&spec, &topo).expect_err("spec must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "error {msg:?} missing {needle:?}");
    };
    let lossy = FaultSpec { loss: 0.1, ..FaultSpec::none() };
    reject(
        RunSpec::amb("loss-exact", 2.0, 0.5, 5, 2, 13)
            .with_consensus(ConsensusMode::Exact)
            .with_faults(lossy.clone()),
        "require a gossip consensus mode",
    );
    reject(
        RunSpec::amb("loss-hier", 2.0, 0.5, 5, 2, 13)
            .with_consensus(ConsensusMode::Hierarchical {
                shards: 2,
                intra_rounds: 3,
                inter_rounds: 2,
            })
            .with_faults(lossy),
        "Hierarchical",
    );
    reject(
        RunSpec::amb("loss-range", 2.0, 0.5, 5, 2, 13)
            .with_faults(FaultSpec { loss: 1.5, ..FaultSpec::none() }),
        "not in [0, 1]",
    );
    reject(
        RunSpec::amb("crash-range", 2.0, 0.5, 5, 2, 13).with_faults(FaultSpec {
            crashes: vec![CrashWindow { node: 9, from: 1, to: 2 }],
            ..FaultSpec::none()
        }),
        "names node",
    );
}
