//! The §5 determinism guarantee under the worker pool: a seeded sim run
//! is BITWISE identical at `threads = 1` (serial path) and `threads = 4`
//! (pooled epoch fan-out + row-partitioned consensus kernels), for every
//! `Scheme` × `ConsensusMode`; and the concurrent sweep driver returns
//! results in spec order regardless of completion order.
//!
//! Pool sizing is process-global, so every test here serializes on one
//! lock and restores the environment default before releasing it.

use std::sync::Arc;
use std::sync::Mutex;

mod common;
use common::assert_bitwise_equal;

use anytime_mb::consensus::Consensus;
use anytime_mb::coordinator::{ConsensusMode, RunOutput, RunSpec, Scheme};
use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::experiments::sweep;
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::util::matrix::NodeMatrix;
use anytime_mb::util::pool;
use anytime_mb::Runtime;
use anytime_mb::SimRuntime;

static POOL_LOCK: Mutex<()> = Mutex::new(());

fn run_sim(spec: &RunSpec) -> RunOutput {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(24, 5)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 400.0), 4.0 * 24f64.sqrt());
    let f_star = src.f_star();
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    SimRuntime::new(&strag).run(spec, &topo, &mk, f_star).unwrap()
}

#[test]
fn sim_threads1_equals_threads4_for_every_scheme_and_mode() {
    let _guard = POOL_LOCK.lock().unwrap();
    let schemes: [Scheme; 6] = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: false },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 },
    ];
    let modes: [ConsensusMode; 3] = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ];
    for scheme in schemes {
        for mode in modes {
            let spec = RunSpec::new(scheme.name(), scheme, 5, 13).with_consensus(mode);
            pool::set_threads(1);
            let serial = run_sim(&spec);
            pool::set_threads(4);
            let pooled = run_sim(&spec);
            assert_bitwise_equal(
                &serial,
                &pooled,
                &format!("{} × {:?}", scheme.name(), mode),
            );
        }
    }
    pool::clear_threads_override();
}

/// The bitwise threads=1 ≡ threads=k contract must hold for CHURN runs
/// too (ISSUE 4): churned epochs mix with induced matrices through the
/// same row-partitioned kernels, and the per-node update mask is applied
/// identically by the serial path and the pooled node blocks.
#[test]
fn sim_threads1_equals_threads4_under_churn() {
    use anytime_mb::churn::ChurnSpec;
    let _guard = POOL_LOCK.lock().unwrap();
    let schemes: [Scheme; 4] = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true },
        // AMB-DG's pipeline rings live INSIDE the pooled node blocks —
        // the bitwise contract must hold for the delayed scheme while
        // membership fluctuates (frozen rings, rejoin staleness).
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 },
    ];
    let modes: [ConsensusMode; 3] = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ];
    for scheme in schemes {
        for mode in modes {
            let spec = RunSpec::new(scheme.name(), scheme, 5, 13)
                .with_consensus(mode)
                .with_churn(ChurnSpec::IidDropout { p: 0.25, seed: 31 });
            pool::set_threads(1);
            let serial = run_sim(&spec);
            pool::set_threads(4);
            let pooled = run_sim(&spec);
            assert_eq!(serial.active_counts, pooled.active_counts);
            assert_bitwise_equal(
                &serial,
                &pooled,
                &format!("churn {} × {:?}", scheme.name(), mode),
            );
        }
    }
    pool::clear_threads_override();
}

#[test]
fn row_partitioned_kernels_are_thread_count_invariant() {
    let _guard = POOL_LOCK.lock().unwrap();
    // straddle the MIX_TILE boundary and the per-thread work gate
    let topo = Topology::expander(48, 6, 3);
    let p = topo.metropolis().lazy();
    let mut seed = NodeMatrix::new(48, 2048 + 7);
    let mut v = 0.37f32;
    for x in seed.as_mut_slice() {
        v = (v * 1.7).sin();
        *x = v * 3.0;
    }

    pool::set_threads(1);
    let mut serial = seed.clone();
    Consensus::new(p.clone()).run(&mut serial, 4);
    let avg_serial = Consensus::exact_average(&seed).unwrap();

    pool::set_threads(4);
    let mut pooled = seed.clone();
    Consensus::new(p).run(&mut pooled, 4);
    let avg_pooled = Consensus::exact_average(&seed).unwrap();

    for (a, b) in serial.as_slice().iter().zip(pooled.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "mix kernel drifted with thread count");
    }
    for (a, b) in avg_serial.iter().zip(&avg_pooled) {
        assert_eq!(a.to_bits(), b.to_bits(), "exact_average drifted with thread count");
    }
    pool::clear_threads_override();
}

#[test]
fn sweep_driver_returns_results_in_spec_order() {
    let _guard = POOL_LOCK.lock().unwrap();
    pool::set_threads(4);
    // Epoch counts descend, so spec 0 takes the longest and (with work
    // stealing) finishes LAST — completion order is the reverse of spec
    // order, which is exactly what the ordering contract must survive.
    let epochs = [8usize, 5, 3, 2, 1];
    let outs = sweep::sweep(epochs.len(), |i| {
        let spec = RunSpec::amb(&format!("sweep-{i}"), 2.0, 0.5, 4, epochs[i], 29);
        Ok(run_sim(&spec))
    })
    .unwrap();
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(out.record.name, format!("sweep-{i}"), "sweep reordered results");
        assert_eq!(out.record.epochs.len(), epochs[i]);
    }
    // ... and sweep items see a serial inner pool (no nested fan-out).
    let inner = sweep::sweep(3, |_| Ok(pool::current_threads())).unwrap();
    assert_eq!(inner, vec![1, 1, 1]);
    pool::clear_threads_override();
}
