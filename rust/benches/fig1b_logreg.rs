//! Bench: Figure 1(b) — MNIST-shaped logistic regression, AMB vs FMB.

use std::sync::Arc;

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::RunSpec;
use anytime_mb::exec::{ExecEngine, NativeExec};
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::SimRuntime;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig1::fig1b(&ctx).expect("fig1b");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 8.0, lambda: 0.25, unit_batch: 800 };
    let source = experiments::mnist_source(1);
    let opt = experiments::optimizer_for(&source, 8000.0);
    let f_star = source.f_star();
    let src = Arc::clone(&source);
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    let sim = SimRuntime::new(&strag);

    let amb = RunSpec::amb("amb", 12.0, 3.0, 5, 2, 1);
    b.bench_run("fig1b/amb_2_epochs_n10_k10_d785", &sim, &amb, &topo, &mk, f_star);
    b.report("fig1b logreg EC2");
}
