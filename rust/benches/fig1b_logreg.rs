//! Bench: Figure 1(b) — MNIST-shaped logistic regression, AMB vs FMB.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::{sim, RunConfig};
use anytime_mb::exec::NativeExec;
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig1::fig1b(&ctx).expect("fig1b");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 8.0, lambda: 0.25, unit_batch: 800 };
    let source = experiments::mnist_source(1);
    let opt = experiments::optimizer_for(&source, 8000.0);
    let f_star = source.f_star();

    b.bench("fig1b/amb_2_epochs_n10_k10_d785", || {
        let cfg = RunConfig::amb("amb", 12.0, 3.0, 5, 2, 1);
        let src = source.clone();
        let o = opt.clone();
        sim::run(&cfg, &topo, &strag, move |_| Box::new(NativeExec::new(src.clone(), o.clone())), f_star)
            .record
            .total_samples()
    });
    b.report("fig1b logreg EC2");
}
