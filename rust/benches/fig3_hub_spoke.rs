//! Bench: Figure 3 — hub-and-spoke (master-worker) logistic regression.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::{sim, ConsensusMode, RunConfig};
use anytime_mb::exec::NativeExec;
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig3::fig3(&ctx).expect("fig3");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::complete(19);
    let strag = ShiftedExp { zeta: 2.0, lambda: 1.0, unit_batch: 210 };
    let source = experiments::mnist_source(1);
    let opt = experiments::optimizer_for(&source, 3990.0);
    let f_star = source.f_star();

    b.bench("fig3/amb_hub_2_epochs_19_workers", || {
        let cfg = RunConfig::amb("amb", 3.0, 1.0, 1, 2, 1).with_consensus(ConsensusMode::Exact);
        let src = source.clone();
        let o = opt.clone();
        sim::run(&cfg, &topo, &strag, move |_| Box::new(NativeExec::new(src.clone(), o.clone())), f_star)
            .record
            .total_samples()
    });
    b.report("fig3 hub-and-spoke");
}
