//! Bench: Figure 3 — hub-and-spoke (master-worker) logistic regression.

use std::sync::Arc;

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::{ConsensusMode, RunSpec};
use anytime_mb::exec::{ExecEngine, NativeExec};
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::SimRuntime;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig3::fig3(&ctx).expect("fig3");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::complete(19);
    let strag = ShiftedExp { zeta: 2.0, lambda: 1.0, unit_batch: 210 };
    let source = experiments::mnist_source(1);
    let opt = experiments::optimizer_for(&source, 3990.0);
    let f_star = source.f_star();
    let src = Arc::clone(&source);
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    let sim = SimRuntime::new(&strag);

    let spec = RunSpec::amb("amb", 3.0, 1.0, 1, 2, 1).with_consensus(ConsensusMode::Exact);
    b.bench_run("fig3/amb_hub_2_epochs_19_workers", &sim, &spec, &topo, &mk, f_star);
    b.report("fig3 hub-and-spoke");
}
