//! Bench: Figure 1(a) — linreg AMB vs FMB on simulated EC2.
//! Regenerates the figure (quick mode) and times the epoch pipeline via
//! the unified `RunSpec` → `amb::run` API.

use std::sync::Arc;

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::RunSpec;
use anytime_mb::exec::{ExecEngine, NativeExec};
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::SimRuntime;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig1::fig1a(&ctx).expect("fig1a");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 12.5, lambda: 0.5, unit_batch: 600 };
    let source = experiments::linreg_source(1);
    let opt = experiments::optimizer_for(&source, 6000.0);
    let f_star = source.f_star();
    let src = Arc::clone(&source);
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    let sim = SimRuntime::new(&strag);

    let amb = RunSpec::amb("amb", 14.5, 4.5, 5, 5, 1);
    b.bench_run("fig1a/amb_5_epochs_n10_d1024", &sim, &amb, &topo, &mk, f_star);
    let fmb = RunSpec::fmb("fmb", 600, 4.5, 5, 5, 1);
    b.bench_run("fig1a/fmb_5_epochs_n10_d1024", &sim, &fmb, &topo, &mk, f_star);
    b.report("fig1a linreg EC2");
}
