//! Bench: Figure 1(a) — linreg AMB vs FMB on simulated EC2.
//! Regenerates the figure (quick mode) and times the epoch pipeline.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::coordinator::{sim, RunConfig};
use anytime_mb::exec::NativeExec;
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig1::fig1a(&ctx).expect("fig1a");
    println!("{report}");

    let mut b = Bencher::quick();
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 12.5, lambda: 0.5, unit_batch: 600 };
    let source = experiments::linreg_source(1);
    let opt = experiments::optimizer_for(&source, 6000.0);
    let f_star = source.f_star();

    b.bench("fig1a/amb_5_epochs_n10_d1024", || {
        let cfg = RunConfig::amb("amb", 14.5, 4.5, 5, 5, 1);
        let src = source.clone();
        let o = opt.clone();
        sim::run(&cfg, &topo, &strag, move |_| Box::new(NativeExec::new(src.clone(), o.clone())), f_star)
            .record
            .total_time()
    });
    b.bench("fig1a/fmb_5_epochs_n10_d1024", || {
        let cfg = RunConfig::fmb("fmb", 600, 4.5, 5, 5, 1);
        let src = source.clone();
        let o = opt.clone();
        sim::run(&cfg, &topo, &strag, move |_| Box::new(NativeExec::new(src.clone(), o.clone())), f_star)
            .record
            .total_time()
    });
    b.report("fig1a linreg EC2");
}
