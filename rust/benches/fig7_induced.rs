//! Bench: Figure 7 — logistic regression with induced stragglers.

use anytime_mb::experiments::{self, Ctx};

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let t0 = std::time::Instant::now();
    let report = experiments::fig7::fig7(&ctx).expect("fig7");
    println!("{report}");
    println!("fig7 quick regeneration: {:.2}s", t0.elapsed().as_secs_f64());
}
