//! Bench: Figure 5 — r=5 vs r=∞ consensus; times the consensus engine
//! itself across round budgets and dimensions.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::consensus::Consensus;
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::topology::Topology;
use anytime_mb::util::matrix::NodeMatrix;
use anytime_mb::util::rng::Pcg64;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig5::fig5(&ctx).expect("fig5");
    println!("{report}");

    let mut b = Bencher::quick();
    for (n, d, rounds) in [(10, 1024, 5), (10, 7850, 5), (20, 1024, 5), (10, 1024, 50)] {
        let topo = Topology::erdos_connected(n, 0.3, 1);
        let mut cons = Consensus::new(topo.metropolis().lazy());
        let mut rng = Pcg64::new(2);
        let mut msgs0 = NodeMatrix::new(n, d);
        for v in msgs0.as_mut_slice() {
            *v = rng.normal() as f32;
        }
        b.bench(&format!("consensus/n{n}_d{d}_r{rounds}"), || {
            let mut msgs = msgs0.clone();
            cons.run(&mut msgs, rounds);
            msgs.row(0)[0]
        });
    }
    b.report("fig5 consensus engine");
}
