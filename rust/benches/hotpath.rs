//! Hot-path microbenchmarks across all three layers (EXPERIMENTS.md §Perf):
//!
//!   L3 native  — consensus round, gradient chunk, primal step, full
//!                simulated epoch
//!   RT (PJRT)  — artifact-backed gradient chunk + dual update (requires
//!                `make artifacts`; skipped otherwise)
//!
//! These are the numbers the §Perf iteration log tracks.  Besides the
//! printed tables, every row lands in machine-readable form in
//! `BENCH_hotpath.json` at the workspace root (the bench trajectory the
//! ISSUE-3 acceptance criteria read), including the serial-vs-parallel
//! scaling grid: threads ∈ {1, 2, 4} × the n/d consensus grid plus the
//! pool-fanned simulated epoch.

use std::rc::Rc;
use std::sync::Arc;

use anytime_mb::bench_harness::{legacy_vecvec_mix_into, Bencher};
use anytime_mb::consensus::{sparse::SparseMix, Consensus};
use anytime_mb::coordinator::RunSpec;
use anytime_mb::data::{LinRegStream, MnistLike};
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::runtime::{PjrtExec, PjrtRuntime};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::util::json::Json;
use anytime_mb::util::matrix::NodeMatrix;
use anytime_mb::util::pool;
use anytime_mb::util::rng::Pcg64;
use anytime_mb::SimRuntime;

fn optimizer(dim: usize) -> DualAveraging {
    DualAveraging::new(BetaSchedule::new(1.0, 1000.0), 4.0 * (dim as f64).sqrt())
}

fn random_arena(rng: &mut Pcg64, n: usize, d: usize) -> NodeMatrix {
    let mut m = NodeMatrix::new(n, d);
    for v in m.as_mut_slice() {
        *v = rng.normal() as f32;
    }
    m
}

fn main() {
    let mut b = Bencher::new();

    // ---- L3: consensus kernel — nested-Vec baseline vs flat arena ---------
    // The ISSUE-2 acceptance grid: n ∈ {10, 64} × d ∈ {1024, 8192},
    // 5 gossip rounds in place (zero per-round allocations on the flat
    // paths; the legacy path is the pre-arena data plane).  Speedup rows
    // are printed below the table.  Pinned to ONE pool thread so this
    // table isolates PR-2's layout win from PR-3's threading (which the
    // dedicated t ∈ {1, 2, 4} scaling grid measures separately) and the
    // recorded JSON doesn't vary with the host's core count.
    pool::set_threads(1);
    let mut rng = Pcg64::new(1);
    let mut grid_rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (label, topo) in
        [("n10_fig2", Topology::paper_fig2()), ("n64_expander", Topology::expander(64, 6, 2))]
    {
        for d in [1024usize, 8192] {
            let n = topo.n();
            let p = topo.metropolis().lazy();

            let seed_rows = random_arena(&mut rng, n, d);

            let mut legacy = seed_rows.to_rows();
            let mut legacy_scratch = vec![vec![0.0f32; d]; n];
            let t_legacy = b
                .bench(&format!("L3/consensus_legacy_vecvec_{label}_d{d}_5r"), || {
                    for _ in 0..5 {
                        legacy_vecvec_mix_into(&p, &legacy, &mut legacy_scratch);
                        std::mem::swap(&mut legacy, &mut legacy_scratch);
                    }
                    legacy[0][0]
                })
                .mean;

            let mut cons = Consensus::new(p.clone());
            let mut msgs = seed_rows.clone();
            let t_flat = b
                .bench(&format!("L3/consensus_flat_dense_{label}_d{d}_5r"), || {
                    cons.run(&mut msgs, 5);
                    msgs.row(0)[0]
                })
                .mean;

            let sparse = SparseMix::metropolis(&topo, true);
            let mut smsgs = seed_rows.clone();
            let mut scratch = NodeMatrix::new(0, 0);
            let t_sparse = b
                .bench(&format!("L3/consensus_flat_sparse_{label}_d{d}_5r"), || {
                    sparse.run(&mut smsgs, &mut scratch, 5);
                    smsgs.row(0)[0]
                })
                .mean;

            grid_rows.push((format!("{label}_d{d}"), t_legacy, t_flat, t_sparse));
        }
    }

    // ---- L3: n-scaling grid (ISSUE 7) — the plane from n=64 to n=1e5 -------
    // Narrow d keeps the per-round cost ∝ nnz·d, so these rows time the
    // MIXING layer itself: CSR build (never materialises n² entries) and
    // 5 gossip rounds.  The legacy dense-walk baseline (one `at(i, j)`
    // probe per matrix entry) runs only at n ≤ 1024 — at n = 10⁵ a dense
    // P would be 10¹⁰ entries before the first round, which is exactly
    // what the sparse-first representation exists to avoid.  Still under
    // the 1-thread pin, so the JSON is host-independent.
    let mut nscale_rows: Vec<(String, usize, usize, f64, f64, Option<f64>)> = Vec::new();
    {
        let d = 16usize;
        for n in [64usize, 1024, 16384, 100_000] {
            for fam in ["ring", "small_world"] {
                let topo = match fam {
                    "ring" => Topology::ring(n),
                    _ => Topology::small_world(n, 3, 0.1, 7),
                };
                let label = format!("{fam}_n{n}");
                let t_build = b
                    .bench(&format!("L3/csr_build_{label}"), || topo.metropolis().lazy().nnz())
                    .mean;
                let p = topo.metropolis().lazy();
                let nnz = p.nnz();
                let seed_rows = random_arena(&mut rng, n, d);
                let mut cons = Consensus::new(p.clone());
                let mut msgs = seed_rows.clone();
                let t_mix = b
                    .bench(&format!("L3/consensus_sparse_{label}_d{d}_5r"), || {
                        cons.run(&mut msgs, 5);
                        msgs.row(0)[0]
                    })
                    .mean;
                let t_legacy = (n <= 1024).then(|| {
                    let mut legacy = seed_rows.to_rows();
                    let mut scratch = vec![vec![0.0f32; d]; n];
                    b.bench(&format!("L3/consensus_legacy_densewalk_{label}_d{d}_5r"), || {
                        for _ in 0..5 {
                            legacy_vecvec_mix_into(&p, &legacy, &mut scratch);
                            std::mem::swap(&mut legacy, &mut scratch);
                        }
                        legacy[0][0]
                    })
                    .mean
                });
                nscale_rows.push((label, n, nnz, t_build, t_mix, t_legacy));
            }
        }
    }
    // (the 1-thread pin stays on through the baseline rows below — the
    // gradient/primal benches never touch the pool, and the baseline
    // sim-epoch row must stay host-independent and comparable to the
    // pre-pool trajectory; the scaling grid re-pins per point)

    // ---- L3: native gradient chunks ----------------------------------------
    let lin_src = Arc::new(DataSource::LinReg(LinRegStream::new(1024, 2)));
    let mut lin_exec = NativeExec::new(lin_src, optimizer(1024));
    let w1024: Vec<f32> = (0..1024).map(|_| rng.normal() as f32 * 0.1).collect();
    let mut acc1024 = vec![0.0f32; 1024];
    let mut data_rng = Pcg64::new(3);
    b.bench("L3/native_linreg_grad_256x1024", || {
        acc1024.fill(0.0);
        lin_exec.grad_chunk(&w1024, 256, &mut data_rng, &mut acc1024)
    });

    let log_src = Arc::new(DataSource::Mnist(MnistLike::mnist_shaped(4)));
    let mut log_exec = NativeExec::new(log_src, optimizer(7850));
    let w7850: Vec<f32> = (0..7850).map(|_| rng.normal() as f32 * 0.01).collect();
    let mut acc7850 = vec![0.0f32; 7850];
    b.bench("L3/native_logreg_grad_128x10x785", || {
        acc7850.fill(0.0);
        log_exec.grad_chunk(&w7850, 128, &mut data_rng, &mut acc7850)
    });

    // ---- L3: primal step ----------------------------------------------------
    let opt = optimizer(7850);
    let z: Vec<f32> = (0..7850).map(|_| rng.normal() as f32).collect();
    let mut wbuf = vec![0.0f32; 7850];
    b.bench("L3/primal_step_d7850", || {
        opt.primal_step(&z, 10, &mut wbuf);
        wbuf[0]
    });

    // ---- L3: full simulated epoch (the figure-harness inner loop) ----------
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let sim_src = Arc::new(DataSource::LinReg(LinRegStream::new(1024, 5)));
    let sim_opt = optimizer(1024);
    let f_star = sim_src.f_star();
    let epoch_src = sim_src.clone();
    let epoch_mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(epoch_src.clone(), sim_opt.clone()))
    };
    let epoch_spec = RunSpec::amb("amb", 2.5, 0.5, 5, 1, 7);
    b.bench_run(
        "L3/sim_epoch_amb_n10_d1024_b6000",
        &SimRuntime::new(&strag),
        &epoch_spec,
        &topo,
        &epoch_mk,
        f_star,
    );

    // ---- pool scaling: threads ∈ {1, 2, 4} over the hot parallel paths ----
    // Results are bit-identical at every thread count (the pool only
    // re-partitions work — tests/parallel_determinism.rs); this grid
    // measures what the partitioning buys.  threads=1 forces the serial
    // path, so each row's speedup column is parallel-vs-serial directly.
    let mut scaling_rows: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    for (label, grid_topo) in
        [("n10_fig2", Topology::paper_fig2()), ("n64_expander", Topology::expander(64, 6, 2))]
    {
        for d in [1024usize, 8192] {
            let n = grid_topo.n();
            let p = grid_topo.metropolis().lazy();
            let seed_rows = random_arena(&mut rng, n, d);
            let mut pts = Vec::new();
            for threads in [1usize, 2, 4] {
                pool::set_threads(threads);
                let mut cons = Consensus::new(p.clone());
                let mut msgs = seed_rows.clone();
                let t = b
                    .bench(&format!("L3/consensus_flat_dense_{label}_d{d}_5r_t{threads}"), || {
                        cons.run(&mut msgs, 5);
                        msgs.row(0)[0]
                    })
                    .mean;
                pts.push((threads, t));
            }
            scaling_rows.push((format!("{label}_d{d}"), pts));
        }
    }
    // The simulated epoch fans per-node gradient work across the pool.
    let mut pts = Vec::new();
    for threads in [1usize, 2, 4] {
        pool::set_threads(threads);
        let t = b
            .bench_run(
                &format!("L3/sim_epoch_amb_n10_d1024_b6000_t{threads}"),
                &SimRuntime::new(&strag),
                &epoch_spec,
                &topo,
                &epoch_mk,
                f_star,
            )
            .mean;
        pts.push((threads, t));
    }
    scaling_rows.push(("sim_epoch_amb_n10_d1024".to_string(), pts));
    pool::clear_threads_override();

    // ---- RT: PJRT artifact path --------------------------------------------
    match PjrtRuntime::load(&anytime_mb::artifacts_dir()) {
        Ok(rt) => {
            let rt = Rc::new(rt);
            let d = rt.manifest.linreg_d;
            let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, 6)));
            let mut pjrt = PjrtExec::new(rt.clone(), src, optimizer(d)).unwrap();
            let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
            let mut acc = vec![0.0f32; d];
            let chunk = rt.manifest.linreg_c;
            b.bench(&format!("RT/pjrt_linreg_grad_{chunk}x{d}"), || {
                acc.fill(0.0);
                pjrt.grad_chunk(&w, chunk, &mut data_rng, &mut acc)
            });
            b.bench(&format!("RT/pjrt_linreg_grad_600_samples_d{d}"), || {
                acc.fill(0.0);
                pjrt.grad_chunk(&w, 600, &mut data_rng, &mut acc)
            });
            let z: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut wp = vec![0.0f32; d];
            b.bench(&format!("RT/pjrt_dual_update_d{d}"), || {
                pjrt.primal_step(&z, 5, &mut wp);
                wp[0]
            });
        }
        Err(e) => println!("(PJRT benches skipped: {e})"),
    }

    b.report("hotpath microbenchmarks");

    // Before/after table for the NodeMatrix data-plane swap (the numbers
    // the ISSUE-2 acceptance criteria track: flat ≥ 2× legacy at
    // n=64, d=8192).
    println!("\n== consensus kernel: legacy Vec<Vec<f32>> vs flat NodeMatrix (5 rounds) ==");
    for (name, t_legacy, t_flat, t_sparse) in &grid_rows {
        println!(
            "  {:<22} legacy {:>9} | flat dense {:>9} ({:.2}x) | flat sparse {:>9} ({:.2}x)",
            name,
            anytime_mb::bench_harness::fmt_time(*t_legacy),
            anytime_mb::bench_harness::fmt_time(*t_flat),
            t_legacy / t_flat,
            anytime_mb::bench_harness::fmt_time(*t_sparse),
            t_legacy / t_sparse,
        );
    }

    // n-scaling table (the ISSUE-7 acceptance bar: build + mix stay
    // ∝ nnz while the dense walk, where it can run at all, falls behind).
    println!("\n== n-scaling: CSR build + 5 sparse rounds, d=16 (1 thread) ==");
    for (name, n, nnz, t_build, t_mix, t_legacy) in &nscale_rows {
        let legacy_cell = match t_legacy {
            Some(t) => format!(
                "densewalk {:>9} ({:.1}x)",
                anytime_mb::bench_harness::fmt_time(*t),
                t / t_mix
            ),
            None => format!("densewalk —         (n²={:.1e})", (*n as f64) * (*n as f64)),
        };
        println!(
            "  {:<22} nnz {:>8} | build {:>9} | mix {:>9} | {}",
            name,
            nnz,
            anytime_mb::bench_harness::fmt_time(*t_build),
            anytime_mb::bench_harness::fmt_time(*t_mix),
            legacy_cell,
        );
    }

    // Serial-vs-parallel scaling table (the ISSUE-3 acceptance bar:
    // >1x on the n=64, d=8192 grid when more than one core exists).
    println!("\n== pool scaling: threads ∈ {{1, 2, 4}} (speedup vs t=1) ==");
    for (name, pts) in &scaling_rows {
        let t1 = pts[0].1;
        let cells: Vec<String> = pts
            .iter()
            .map(|&(t, m)| {
                format!("t={t} {:>9} ({:.2}x)", anytime_mb::bench_harness::fmt_time(m), t1 / m)
            })
            .collect();
        println!("  {:<26} {}", name, cells.join(" | "));
    }

    // Derived throughput lines for §Perf.
    for s in b.results() {
        let items = match s.name.as_str() {
            "L3/native_linreg_grad_256x1024" => Some(256.0 * 1024.0 * 2.0),
            "L3/native_logreg_grad_128x10x785" => Some(128.0 * 7850.0 * 4.0),
            n if n.starts_with("RT/pjrt_linreg_grad_256") => Some(256.0 * 1024.0 * 2.0),
            _ => None,
        };
        if let Some(flops) = items {
            println!(
                "  {:<42} ~{:.2} GFLOP/s",
                s.name,
                flops / s.mean / 1e9
            );
        }
    }

    // Machine-readable trajectory: every timed row + the two derived
    // grids, at the workspace root so successive runs are diffable.
    let doc = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        (
            "detected_parallelism",
            Json::num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
        ),
        (
            "results",
            Json::arr(b.results().iter().map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("iters", Json::num(s.iters as f64)),
                    ("mean_s", Json::num(s.mean)),
                    ("stddev_s", Json::num(s.stddev)),
                    ("p50_s", Json::num(s.p50)),
                    ("p95_s", Json::num(s.p95)),
                    ("min_s", Json::num(s.min)),
                ])
            })),
        ),
        (
            "legacy_vs_flat",
            Json::arr(grid_rows.iter().map(|(name, t_legacy, t_flat, t_sparse)| {
                Json::obj(vec![
                    ("grid", Json::str(name)),
                    ("legacy_s", Json::num(*t_legacy)),
                    ("flat_dense_s", Json::num(*t_flat)),
                    ("flat_sparse_s", Json::num(*t_sparse)),
                    ("dense_speedup", Json::num(t_legacy / t_flat)),
                    ("sparse_speedup", Json::num(t_legacy / t_sparse)),
                ])
            })),
        ),
        (
            "n_scaling",
            Json::arr(nscale_rows.iter().map(|(name, n, nnz, t_build, t_mix, t_legacy)| {
                let mut fields = vec![
                    ("grid", Json::str(name)),
                    ("n", Json::num(*n as f64)),
                    ("nnz", Json::num(*nnz as f64)),
                    ("csr_build_s", Json::num(*t_build)),
                    ("sparse_mix5_s", Json::num(*t_mix)),
                ];
                if let Some(t) = t_legacy {
                    fields.push(("legacy_densewalk_mix5_s", Json::num(*t)));
                    fields.push(("dense_vs_sparse_speedup", Json::num(t / t_mix)));
                }
                Json::obj(fields)
            })),
        ),
        (
            "thread_scaling",
            Json::arr(scaling_rows.iter().map(|(name, pts)| {
                Json::obj(vec![
                    ("grid", Json::str(name)),
                    ("threads", Json::arr(pts.iter().map(|&(t, _)| Json::num(t as f64)))),
                    ("mean_s", Json::arr(pts.iter().map(|&(_, m)| Json::num(m)))),
                    (
                        "speedup_vs_t1",
                        Json::arr(pts.iter().map(|&(_, m)| Json::num(pts[0].1 / m))),
                    ),
                ])
            })),
        ),
    ]);
    let json_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_hotpath.json");
    match std::fs::write(&json_path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json_path.display()),
    }
}
