//! Bench: design-choice ablations (rounds, b̂(t), consensus engines,
//! redundancy baselines, topology) — see experiments/ablations.rs.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::consensus::{sparse::SparseMix, Consensus};
use anytime_mb::experiments::{ablations, Ctx};
use anytime_mb::topology::Topology;
use anytime_mb::util::matrix::NodeMatrix;
use anytime_mb::util::rng::Pcg64;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    for rep in ablations::run_all(&ctx).expect("ablations") {
        println!("{rep}");
    }

    // Dense vs sparse engine timing at figure-scale dimensions.
    let mut b = Bencher::quick();
    for (n, d) in [(10usize, 7851usize), (50, 1024), (100, 1024)] {
        let topo = Topology::erdos_connected(n, 0.1, 1);
        let mut dense = Consensus::new(topo.metropolis().lazy());
        let sparse = SparseMix::metropolis(&topo, true);
        let mut rng = Pcg64::new(2);
        let mut msgs0 = NodeMatrix::new(n, d);
        for v in msgs0.as_mut_slice() {
            *v = rng.normal() as f32;
        }
        b.bench(&format!("dense/n{n}_d{d}_5r"), || {
            let mut m = msgs0.clone();
            dense.run(&mut m, 5);
            m.row(0)[0]
        });
        let mut scratch = NodeMatrix::new(0, 0);
        b.bench(&format!("sparse/n{n}_d{d}_5r"), || {
            let mut m = msgs0.clone();
            sparse.run(&mut m, &mut scratch, 5);
            m.row(0)[0]
        });
    }
    b.report("consensus engine ablation");
}
