//! Bench: Figure 6 — induced-straggler histograms; times the straggler
//! model sampling hot path.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::experiments::{self, Ctx};
use anytime_mb::straggler::{InducedGroups, PauseModel, StragglerModel};
use anytime_mb::util::rng::Pcg64;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::fig6::fig6(&ctx).expect("fig6");
    println!("{report}");

    let mut b = Bencher::quick();
    let induced = InducedGroups::paper_i3();
    let mut rng = Pcg64::new(1);
    b.bench("straggler/induced_1k_draws", || {
        let mut acc = 0usize;
        for e in 0..1000 {
            let mut p = induced.draw(e % 10, e, &mut rng);
            acc += p.grads_in_time(12.0);
        }
        acc
    });
    let pause = PauseModel::paper_i4();
    b.bench("straggler/pause_100_draws_T115", || {
        let mut acc = 0usize;
        for e in 0..100 {
            let mut p = pause.draw(e % 50, e, &mut rng);
            acc += p.grads_in_time(115.0);
        }
        acc
    });
    b.report("fig6 straggler models");
}
