//! Bench: Theorem 7 — speedup-vs-n sweep + timing of the sweep itself.

use anytime_mb::bench_harness::Bencher;
use anytime_mb::experiments::{self, thm7::speedup_for_n, Ctx};
use anytime_mb::straggler::ShiftedExp;

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let report = experiments::thm7::thm7(&ctx).expect("thm7");
    println!("{report}");

    let mut b = Bencher::quick();
    let model = ShiftedExp::paper_i2();
    for n in [10usize, 100] {
        b.bench(&format!("thm7/sweep_n{n}_100_epochs"), || {
            speedup_for_n(&model, n, 600, 100, 3).measured
        });
    }
    b.report("thm7 speedup sweep");
}
