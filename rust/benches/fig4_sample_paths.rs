//! Bench: Figure 4 — 20 sample paths under shifted-exponential stragglers.

use anytime_mb::experiments::{self, Ctx};

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let t0 = std::time::Instant::now();
    let report = experiments::fig4::fig4(&ctx).expect("fig4");
    println!("{report}");
    println!("fig4 quick regeneration: {:.2}s", t0.elapsed().as_secs_f64());
}
