//! Bench: Figures 8 & 9 — HPC pause-model histograms + cost-vs-time.

use anytime_mb::experiments::{self, Ctx};

fn main() {
    let dir = std::path::PathBuf::from("results/bench");
    let ctx = Ctx::native(&dir).quick();
    let t0 = std::time::Instant::now();
    let r8 = experiments::fig8::fig8(&ctx).expect("fig8");
    println!("{r8}");
    let r9 = experiments::fig8::fig9(&ctx).expect("fig9");
    println!("{r9}");
    println!("fig8+9 quick regeneration: {:.2}s", t0.elapsed().as_secs_f64());
}
