//! Theorem 7 / App. H sweep: how much wall time does AMB save as the
//! cluster grows?  Prints measured S_F/S_A against the paper's
//! (1 + σ/μ·√(n−1)) bound and the shifted-exponential Θ(log n) form,
//! plus a σ/μ sweep showing the speedup scale with compute variability.
//!
//!   cargo run --release --example straggler_sweep

use anytime_mb::experiments::thm7::speedup_for_n;
use anytime_mb::straggler::ShiftedExp;

fn main() {
    println!("== speedup vs n (shifted-exp, ζ=1, λ=2/3, unit 600 — paper App. I.2) ==");
    println!(
        "{:>6} {:>12} {:>12} {:>14} {:>14}",
        "n", "measured", "thm7 bound", "logn analytic", "E[b_amb]/b"
    );
    let model = ShiftedExp::paper_i2();
    for n in [2usize, 5, 10, 20, 50, 100, 200] {
        let p = speedup_for_n(&model, n, 600, 300, 42);
        println!(
            "{:>6} {:>11.2}x {:>11.2}x {:>13.2}x {:>14.3}",
            n,
            p.measured,
            p.thm7_bound,
            p.shifted_exp_analytic,
            p.mean_amb_batch / p.fmb_batch
        );
        assert!(p.measured <= p.thm7_bound * 1.02, "Thm 7 bound violated");
        assert!(p.mean_amb_batch >= p.fmb_batch * 0.97, "Lemma 6 violated");
    }

    println!("\n== speedup vs compute variability (n = 20) ==");
    println!("{:>10} {:>12} {:>12}", "sigma/mu", "measured", "thm7 bound");
    for lambda in [4.0, 2.0, 1.0, 0.5, 0.25] {
        // mean = zeta + 1/lambda, sigma = 1/lambda
        let m = ShiftedExp { zeta: 1.0, lambda, unit_batch: 600 };
        let mom = anytime_mb::straggler::StragglerModel::unit_moments(&m).unwrap();
        let p = speedup_for_n(&m, 20, 600, 300, 7);
        println!(
            "{:>10.2} {:>11.2}x {:>11.2}x",
            mom.stddev / mom.mean,
            p.measured,
            p.thm7_bound
        );
    }
    println!("\nthe paper's claim: more variability ⇒ more AMB advantage, bounded by Thm 7.");
}
