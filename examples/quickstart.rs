//! Quickstart: Anytime Minibatch vs Fixed Minibatch in ~70 lines.
//!
//! A 10-node cluster with shifted-exponential stragglers learns a linear
//! model online; AMB fixes the epoch *time*, FMB fixes the *batch*.
//! Watch the wall-time column: same learning per epoch, very different
//! clocks.  One `RunSpec` drives everything through `anytime_mb::run` —
//! the same spec replays on the discrete-event simulator and then on a
//! real threaded cluster.
//!
//!   cargo run --release --example quickstart

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::{RunSpec, SimRuntime, ThreadedRuntime};

fn main() {
    // 1. A communication graph (the paper's 10-node topology, λ₂ ≈ 0.888).
    let topo = Topology::paper_fig2();

    // 2. A straggler model: each node's time for 600 gradients is
    //    1 + Exp(2/3) seconds — mean 2.5 s, heavy right tail.
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };

    // 3. An online workload: least squares, d = 64, y = x·w* + noise.
    let source = Arc::new(DataSource::LinReg(LinRegStream::new(64, 0)));
    let optimizer = DualAveraging::new(BetaSchedule::new(1.0, 6000.0), 4.0 * 8.0);
    let f_star = source.f_star();
    let src = source.clone();
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), optimizer.clone()))
    };

    // 4. AMB: fixed compute window T = 2.5 s, consensus window 0.5 s,
    //    5 gossip rounds.  FMB: fixed 600 gradients per node.
    let epochs = 15;
    for (label, spec) in [
        ("AMB (fixed time)", RunSpec::amb("amb", 2.5, 0.5, 5, epochs, 1)),
        ("FMB (fixed batch)", RunSpec::fmb("fmb", 600, 0.5, 5, epochs, 1)),
    ] {
        let out = anytime_mb::run(&SimRuntime::new(&strag), &spec, &topo, &mk, f_star).unwrap();
        println!("\n=== {label}, simulated ===");
        println!("{:<6} {:>10} {:>8} {:>12}", "epoch", "wall(s)", "b(t)", "‖w−w*‖²/2");
        for e in out.record.epochs.iter().step_by(3) {
            println!(
                "{:<6} {:>10.1} {:>8} {:>12.4e}",
                e.epoch, e.wall_time, e.batch, e.error
            );
        }
        println!(
            "total: {:.1}s for {} samples (final error {:.3e})",
            out.record.total_time(),
            out.record.total_samples(),
            out.record.epochs.last().unwrap().error
        );
    }
    println!("\nAMB finishes the same number of epochs in deterministic time;");
    println!("FMB waits for the slowest node every epoch.");

    // 5. The SAME spec shape on a real threaded cluster: 100× time
    //    compression (T = 25 ms real), node 0 slowed 3× to induce a
    //    genuine straggler.
    let mut slowdown = vec![1.0; 10];
    slowdown[0] = 3.0;
    let spec = RunSpec::amb("amb-live", 2.5, 0.5, 5, 8, 1)
        .with_time_scale(0.01)
        .with_slowdown(slowdown)
        .with_node_log();
    let out = anytime_mb::run(&ThreadedRuntime, &spec, &topo, &mk, f_star).unwrap();
    let log = out.node_log.as_ref().unwrap();
    let sum = |node: usize| -> usize { log.batches[node].iter().sum() };
    println!("\n=== AMB on 10 real threads (25 ms windows) ===");
    println!(
        "final error {:.3e}; slowed node 0 computed {} samples vs node 9's {} — absorbed, not waited for.",
        out.record.epochs.last().unwrap().error,
        sum(0),
        sum(9),
    );
}
