//! Reproduce Figure 1(a): linear regression, AMB vs FMB on the simulated
//! EC2 cluster (n = 10, paper Fig-2 topology, T = 14.5 s, T_c = 4.5 s).
//!
//!   cargo run --release --example linreg_ec2 [-- --pjrt] [-- --quick]
//!   cargo run --release --example linreg_ec2 -- --runtime threaded --time-scale 0.002
//!
//! With `--pjrt` the per-node gradients run through the AOT-compiled
//! HLO artifacts (requires `make artifacts`); without it they use the
//! native-Rust oracle (identical numerics, see rust/tests/pjrt_roundtrip).
//! With `--runtime threaded` the same RunSpecs execute on the real
//! threaded cluster (windows compressed by `--time-scale`).

use anytime_mb::experiments::{fig1, Ctx};
use anytime_mb::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", "results"));
    // Shared flag parsing (--pjrt, --quick, --seed, --runtime, --time-scale).
    let ctx = Ctx::from_args(&out_dir, &args)?;

    let report = fig1::fig1a(&ctx)?;
    println!("{report}");

    // Print the two series side by side, like the paper's plot.
    for name in ["fig1a_amb", "fig1a_fmb"] {
        let path = out_dir.join(format!("{name}.csv"));
        let text = std::fs::read_to_string(&path)?;
        println!("--- {name} (wall_time, error) ---");
        for line in text.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            println!("  t={:>8}s  err={}", cells[1], cells[5]);
        }
    }
    anyhow::ensure!(report.shape_holds, "figure diverged from the paper's shape");
    Ok(())
}
