//! END-TO-END driver: train a transformer language model with Anytime
//! Minibatch on a real threaded cluster, gradients computed through the
//! AOT-compiled JAX/Pallas artifacts via PJRT — every layer of the stack
//! composing (DESIGN.md §4, row E2E):
//!
//!   L1 Pallas fused softmax-xent  →  L2 JAX GPT fwd/bwd  →  HLO text
//!   →  rust PJRT runtime  →  threaded AMB cluster (this file).
//!
//! Four worker threads share the machine; one is artificially slowed 3×
//! (induced straggler).  Each epoch gives workers a fixed real-time
//! compute window, then a consensus window; the per-token loss falls from
//! ≈ln(V) toward the entropy of the synthetic token grammar.  The loss
//! curve is logged to results/e2e_transformer.csv and summarized in
//! EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example e2e_transformer
//!   (options: --epochs N --t-compute S --t-consensus S --nodes N)

use std::sync::Arc;

use anytime_mb::data::TokenStream;
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::runtime::{Manifest, PjrtRuntime, TransformerExec};
use anytime_mb::topology::Topology;
use anytime_mb::util::cli::Args;
use anytime_mb::coordinator::GOSSIP_UNTIL_DEADLINE;
use anytime_mb::{RunSpec, ThreadedRuntime};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(anytime_mb::artifacts_dir);
    let epochs = args.usize_or("epochs", 30)?;
    let nodes = args.usize_or("nodes", 4)?.max(2);
    let t_compute = args.f64_or("t-compute", 2.5)?;
    let t_consensus = args.f64_or("t-consensus", 0.5)?;
    let seed = args.u64_or("seed", 42)?;

    let probe = Manifest::load(&artifacts)?;
    println!(
        "transformer LM: {} params | vocab {} | seq {} | layers {} | d_model {}",
        probe.transformer.param_count,
        probe.transformer.vocab,
        probe.transformer.seq_len,
        probe.transformer.n_layers,
        probe.transformer.d_model,
    );
    println!(
        "cluster: {nodes} threads, ring topology, T = {t_compute}s, T_c = {t_consensus}s, node 0 slowed 3x"
    );

    let tokens = Arc::new(TokenStream::new(probe.transformer.vocab, seed ^ 0x70));
    // Dual averaging centred at the build-time init (h = ½‖w − w₀‖²).
    // z accumulates per-token-average gradients, so 1/β(t) plays the role
    // of a learning rate: β(1) ≈ 110 ⇒ ~9e-3, decaying like √t.
    let optimizer = DualAveraging::new(
        BetaSchedule::new(args.f64_or("beta-k", 100.0)?, args.f64_or("beta-mu", 0.01)?),
        args.f64_or("radius", 500.0)?,
    );

    let mut slowdown = vec![1.0; nodes];
    slowdown[0] = 3.0; // induced straggler — AMB absorbs it by design

    // As many gossip rounds as fit in T_c; per-(node, epoch) log on.
    let spec = RunSpec::amb("e2e-transformer", t_compute, t_consensus, GOSSIP_UNTIL_DEADLINE, epochs, seed)
        .with_grad_chunk(probe.transformer.batch)
        .with_slowdown(slowdown)
        .with_node_log();
    let topo = Topology::ring(nodes);

    let dir = artifacts.clone();
    let mk = move |_i: usize| -> Box<dyn anytime_mb::exec::ExecEngine> {
        // Per-thread cache: each node thread loads (at most) one runtime.
        let rt = PjrtRuntime::load_shared(&dir).expect("load artifacts");
        Box::new(
            TransformerExec::new(rt, tokens.clone(), optimizer.clone())
                .expect("transformer exec"),
        )
    };
    let t0 = std::time::Instant::now();
    let out = anytime_mb::run(&ThreadedRuntime, &spec, &topo, &mk, None)?;
    let elapsed = t0.elapsed().as_secs_f64();

    // loss column is summed-sequence-loss / sequences; convert to
    // per-token using the artifact seq_len.
    let seq_len = probe.transformer.seq_len as f64;
    println!(
        "\n{:<6} {:>9} {:>8} {:>8} {:>8} {:>12}",
        "epoch", "wall(s)", "b(t)", "min_b", "max_b", "loss/token"
    );
    let mut csv = anytime_mb::util::csv::Csv::new(&[
        "epoch", "wall_time", "batch", "min_node_batch", "max_node_batch", "loss_per_token",
    ]);
    for e in &out.record.epochs {
        let lpt = e.loss / seq_len;
        println!(
            "{:<6} {:>9.2} {:>8} {:>8} {:>8} {:>12.4}",
            e.epoch, e.wall_time, e.batch, e.min_node_batch, e.max_node_batch, lpt
        );
        csv.push_nums(&[
            e.epoch as f64,
            e.wall_time,
            e.batch as f64,
            e.min_node_batch as f64,
            e.max_node_batch as f64,
            lpt,
        ]);
    }
    let out_path = std::path::Path::new("results/e2e_transformer.csv");
    csv.save(out_path)?;

    let first = out.record.epochs.first().unwrap().loss / seq_len;
    let last = out.record.epochs.last().unwrap().loss / seq_len;
    let ln_v = (probe.transformer.vocab as f64).ln();
    println!("\nwrote {}", out_path.display());
    println!(
        "loss/token: {first:.3} (epoch 1, ln V = {ln_v:.3}) -> {last:.3} after {epochs} epochs \
         ({elapsed:.1}s wall, scheduled {:.1}s)",
        epochs as f64 * (t_compute + t_consensus)
    );
    let log = out.node_log.as_ref().expect("spec requested a node log");
    println!(
        "straggler absorbed: node 0 batches {:?}... vs node {} batches {:?}...",
        &log.batches[0][..3.min(log.batches[0].len())],
        nodes - 1,
        &log.batches[nodes - 1][..3.min(log.batches[nodes - 1].len())],
    );
    anyhow::ensure!(last < first, "loss did not decrease: {first} -> {last}");
    Ok(())
}
