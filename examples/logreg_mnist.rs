//! Reproduce Figures 1(b), 7 and 9: multiclass logistic regression
//! (MNIST-shaped synthetic data) under three straggler regimes —
//! clean EC2, EC2 with induced background-job stragglers, and the HPC
//! pause model.  The AMB-over-FMB speedup grows with straggler
//! variability: ≈1.5-1.7× → ≈2× → ≈5× in the paper.
//!
//!   cargo run --release --example logreg_mnist [-- --pjrt] [-- --quick]
//!   cargo run --release --example logreg_mnist -- --runtime threaded --time-scale 0.002

use anytime_mb::experiments::{fig1, fig7, fig8, Ctx};
use anytime_mb::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", "results"));
    // Shared flag parsing (--pjrt, --quick, --seed, --runtime, --time-scale).
    let ctx = Ctx::from_args(&out_dir, &args)?;

    println!("== clean EC2 (Fig 1b) ==");
    let r1 = fig1::fig1b(&ctx)?;
    println!("{r1}");

    println!("== induced stragglers on EC2 (Fig 7) ==");
    let r7 = fig7::fig7(&ctx)?;
    println!("{r7}");

    println!("== HPC pause model, 50 workers (Fig 9) ==");
    let r9 = fig8::fig9(&ctx)?;
    println!("{r9}");

    // The paper's qualitative ordering: speedup grows with variability.
    println!("speedup ordering (paper: 1b < 7 < 9): see measured lines above");
    anyhow::ensure!(
        r1.shape_holds && r7.shape_holds && r9.shape_holds,
        "a figure diverged from the paper's shape"
    );
    Ok(())
}
