"""L1 Pallas kernel: one synchronous round of averaging consensus.

M' = P @ M where P is the (N, N) doubly-stochastic mixing matrix of the
communication graph (paper Sec. 3, consensus phase) and M stacks the N
node messages as rows.  N is tiny (<= 64) while D is the model dimension,
so we tile over D columns and keep all of P resident (P easily fits in
VMEM); each grid step is one (N, N) x (N, BLOCK_D) MXU matmul.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _mix_kernel(p_ref, m_ref, o_ref):
    o_ref[...] = p_ref[...] @ m_ref[...]


def _pick_block(d: int, block_d: int) -> int:
    b = min(block_d, d)
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mix(p, m, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """One consensus round via Pallas: p (N,N) @ m (N,D) -> (N,D).

    Matches ref.mix.
    """
    n, d = m.shape
    bd = _pick_block(d, block_d)
    grid = (d // bd,)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), m.dtype),
        interpret=interpret,
    )(p, m)
