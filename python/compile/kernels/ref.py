"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

Each function here is the *definition* of what the corresponding kernel in
this package must compute.  pytest (python/tests/test_kernels.py) asserts
allclose between kernel and oracle across a hypothesis-driven sweep of
shapes, masks and seeds; the Rust native implementations in
rust/src/model/ are cross-checked against the same formulas in
rust/tests/pjrt_roundtrip.rs.

All gradient kernels return *sums* over masked samples (not means): the
Anytime Minibatch coordinator accumulates chunk sums across a variable
number of chunks and normalises once by the global minibatch size b(t)
(paper eq. (3)-(4)), so the kernels must be linear in the mask.
"""

from __future__ import annotations

import jax.numpy as jnp


def one_hot(labels, num_classes, dtype=jnp.float32):
    """One-hot encode int labels: (B,) -> (B, num_classes)."""
    iota = jnp.arange(num_classes, dtype=jnp.int32)
    return (labels[:, None].astype(jnp.int32) == iota[None, :]).astype(dtype)


def linreg_residual(x, w, y):
    """Residual r = X w - y for a chunk.  x: (C, D), w: (D,), y: (C,)."""
    return x @ w - y


def linreg_grad(x, w, y, mask):
    """Masked sum-of-gradients and sum-of-losses for 0.5 * (x.w - y)^2.

    x: (C, D), w: (D,), y: (C,), mask: (C,) in {0,1}.
    Returns (grad_sum (D,), loss_sum ()):
      grad_sum = X^T (r * mask),  loss_sum = 0.5 * sum(mask * r^2).
    """
    r = linreg_residual(x, w, y)
    rm = r * mask
    grad = x.T @ rm
    loss = 0.5 * jnp.sum(rm * r)
    return grad, loss


def softmax_xent(logits, labels, mask):
    """Masked fused softmax cross-entropy: dlogits + sum loss.

    logits: (B, K) f32, labels: (B,) i32, mask: (B,) f32 in {0,1}.
    Returns (dlogits (B, K), loss_sum ()):
      p       = softmax(logits, axis=-1)
      dlogits = (p - onehot(labels)) * mask[:, None]
      loss    = -sum_b mask_b * log p_b[label_b]
    """
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    ez = jnp.exp(logits - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    p = ez / denom
    dlogits = (p - one_hot(labels, logits.shape[-1], logits.dtype)) * mask[:, None]
    logp = (logits - zmax) - jnp.log(denom)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = -jnp.sum(mask * picked)
    return dlogits, loss


def logreg_grad(w, x, labels, mask):
    """Masked multiclass logistic-regression chunk gradient.

    w: (K, D) f32 (K classes, D features incl. bias), x: (C, D) f32,
    labels: (C,) i32, mask: (C,) f32.
    Returns (grad_sum (K, D), loss_sum ()):
      logits = x @ w.T ; dlogits from softmax_xent ; grad = dlogits.T @ x.
    """
    logits = x @ w.T
    dlogits, loss = softmax_xent(logits, labels, mask)
    grad = dlogits.T @ x
    return grad, loss


def dual_update(z, beta, radius):
    """Dual-averaging primal step, paper eq. (7), h(w) = 0.5 ||w||^2,
    W = L2 ball of the given radius:

      argmin_w <w, z> + beta * 0.5 ||w||^2  s.t. ||w|| <= radius
        = -z / beta, scaled back onto the ball if it lies outside.

    z: (D,) f32, beta: () f32 > 0, radius: () f32 > 0 -> w (D,) f32.
    """
    w = -z / beta
    nrm = jnp.sqrt(jnp.sum(w * w))
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return w * scale


def mix(p, m):
    """One synchronous round of averaging consensus: M' = P @ M.

    p: (N, N) doubly-stochastic f32, m: (N, D) f32 -> (N, D) f32.
    """
    return p @ m
