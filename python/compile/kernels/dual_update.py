"""L1 Pallas kernels: dual-averaging primal update (paper eq. (7)).

With h(w) = 0.5 ||w||^2 and feasible set W = {w : ||w|| <= R},

    w(t+1) = argmin_w <w, z> + beta * h(w)  s.t.  w in W
           = clip_to_ball(-z / beta, R).

Two passes over z, both D-block-tiled (VPU-bound elementwise + reduction;
DESIGN.md §3):

  _sumsq_kernel: partial sums of (z/beta)^2 per block, accumulated into a
                 single scalar across the grid.
  _scale_kernel: w = (-z / beta) * scale with the scalar scale broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elementwise/VPU-bound: big blocks. interpret=True lowers each grid step
# into an XLA loop iteration with real per-step overhead, so a small block
# on a 500k-dim dual vector costs seconds (measured in the e2e example);
# 64k blocks keep the grid a handful of steps while staying far under the
# ~16 MB VMEM budget on real TPUs (64k f32 = 256 KB/buffer).
DEFAULT_BLOCK_D = 65536


def _sumsq_kernel(z_ref, beta_ref, acc_ref):
    j = pl.program_id(0)
    u = z_ref[...] / beta_ref[0]

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(u * u)[None]


def _scale_kernel(z_ref, beta_ref, scale_ref, w_ref):
    w_ref[...] = (-z_ref[...] / beta_ref[0]) * scale_ref[0]


def _pick_block(d: int, block_d: int) -> int:
    b = min(block_d, d)
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def dual_update(z, beta, radius, *, block_d: int = DEFAULT_BLOCK_D,
                interpret: bool = True):
    """Projected dual-averaging step via Pallas.

    z: (D,) f32, beta: () f32 > 0, radius: () f32 > 0 -> w: (D,) f32.
    Matches ref.dual_update.
    """
    (d,) = z.shape
    bd = _pick_block(d, block_d)
    grid = (d // bd,)
    beta_v = jnp.reshape(beta, (1,)).astype(z.dtype)

    sumsq = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), z.dtype),
        interpret=interpret,
    )(z, beta_v)[0]

    nrm = jnp.sqrt(sumsq)
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30)).astype(z.dtype)
    scale_v = jnp.reshape(scale, (1,))

    w = pl.pallas_call(
        _scale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((d,), z.dtype),
        interpret=interpret,
    )(z, beta_v, scale_v)
    return w
