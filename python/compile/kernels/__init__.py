"""L1 Pallas kernels for the Anytime Minibatch hot paths.

All kernels run with interpret=True (the CPU PJRT plugin cannot execute
Mosaic custom-calls); each has a pure-jnp oracle in ref.py and a
hypothesis sweep in python/tests/.
"""

from . import ref  # noqa: F401
from .dual_update import dual_update  # noqa: F401
from .linreg import linreg_grad  # noqa: F401
from .mix import mix  # noqa: F401
from .softmax_xent import softmax_xent, xent_loss  # noqa: F401
