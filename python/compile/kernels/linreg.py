"""L1 Pallas kernels for the linear-regression chunk gradient.

The Anytime Minibatch hot-spot is "sum of per-sample gradients over a
fixed-size chunk with a {0,1} mask" (see DESIGN.md §1: chunk+mask bridges
variable minibatches onto static HLO shapes).  For least squares

    f(w, (x, y)) = 0.5 (x.w - y)^2
    grad_sum     = X^T ((X w - y) * mask)
    loss_sum     = 0.5 * sum(mask * (X w - y)^2)

Two kernels, both tiled over the feature dimension D so a (C, BLOCK_D)
tile of X is resident in VMEM at a time (TPU framing — see DESIGN.md
§3 Hardware adaptation; here they run interpret=True on CPU):

  _residual_kernel: r += X[:, j] @ w[j]  accumulated across the D-grid,
                    initialised to -y at j == 0.
  _grad_kernel:     grad[j] = X[:, j]^T (r * mask), embarrassingly
                    parallel across the D-grid.

The chunk size C is small (<= 1024) so the residual vector lives
comfortably in VMEM for the whole second pass (~4 KB at C=1024).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 1024


def _residual_kernel(x_ref, w_ref, y_ref, r_ref):
    """Grid step j: r += X[:, jD:(j+1)D] @ w[jD:(j+1)D]; init to -y."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        r_ref[...] = -y_ref[...]

    r_ref[...] += x_ref[...] @ w_ref[...]


def _grad_kernel(x_ref, rm_ref, g_ref):
    """Grid step j: grad block j = X_j^T (r * mask) (rm precombined)."""
    g_ref[...] = x_ref[...].T @ rm_ref[...]


def _pick_block(d: int, block_d: int) -> int:
    """Largest divisor of d not exceeding block_d (grid must tile exactly)."""
    b = min(block_d, d)
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def linreg_grad(x, w, y, mask, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """Masked chunk gradient for least squares via Pallas.

    x: (C, D) f32, w: (D,) f32, y: (C,) f32, mask: (C,) f32 in {0,1}.
    Returns (grad_sum (D,) f32, loss_sum () f32).  Matches ref.linreg_grad.
    """
    c, d = x.shape
    bd = _pick_block(d, block_d)
    grid = (d // bd,)

    r = pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, bd), lambda j: (0, j)),
            pl.BlockSpec((bd,), lambda j: (j,)),
            pl.BlockSpec((c,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((c,), x.dtype),
        interpret=interpret,
    )(x, w, y)

    rm = r * mask
    grad = pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, bd), lambda j: (0, j)),
            pl.BlockSpec((c,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=interpret,
    )(x, rm)

    loss = 0.5 * jnp.sum(rm * r)
    return grad, loss
