"""L1 Pallas kernel: fused, masked softmax cross-entropy (loss + grad).

Used by both the multiclass logistic-regression workload (paper Sec. 6.2.2)
and the transformer-LM head of the end-to-end example.  Row-tiled: each
grid step owns a (BLOCK_B, K) tile of logits in VMEM and performs the
single-pass max / logsumexp / softmax / grad computation — the
flash-softmax schedule expressed with BlockSpec instead of threadblocks
(DESIGN.md §3).

Exposes a jax.custom_vjp wrapper `xent_loss` so jax.value_and_grad can
differentiate *through* the Pallas call (Pallas kernels are not
auto-differentiable): the forward kernel already produces dlogits, which
the backward rule simply scales by the output cotangent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _xent_kernel(logits_ref, labels_ref, mask_ref, dlogits_ref, loss_ref):
    """One row-tile: softmax, one-hot grad, masked summed loss."""
    i = pl.program_id(0)
    z = logits_ref[...]                      # (BB, K)
    labels = labels_ref[...]                 # (BB,)
    mask = mask_ref[...]                     # (BB,)

    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    p = ez / denom

    k = z.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (z.shape[0], k), 1)
    onehot = (iota == labels[:, None].astype(jnp.int32)).astype(z.dtype)

    dlogits_ref[...] = (p - onehot) * mask[:, None]

    logp = (z - zmax) - jnp.log(denom)
    picked = jnp.sum(logp * onehot, axis=-1)  # gather via the one-hot
    tile_loss = -jnp.sum(mask * picked)

    @pl.when(i == 0)
    def _():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    loss_ref[...] += tile_loss[None]


def _pick_block(b: int, block_b: int) -> int:
    bb = min(block_b, b)
    while b % bb != 0:
        bb -= 1
    return bb


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def softmax_xent(logits, labels, mask, *, block_b: int = DEFAULT_BLOCK_B,
                 interpret: bool = True):
    """Masked fused softmax cross-entropy via Pallas.

    logits: (B, K) f32, labels: (B,) i32, mask: (B,) f32 in {0,1}.
    Returns (dlogits (B, K), loss_sum () f32).  Matches ref.softmax_xent.
    """
    b, k = logits.shape
    bb = _pick_block(b, block_b)
    grid = (b // bb,)

    dlogits, loss = pl.pallas_call(
        _xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), logits.dtype),
            jax.ShapeDtypeStruct((1,), logits.dtype),
        ],
        interpret=interpret,
    )(logits, labels, mask)
    return dlogits, loss[0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def xent_loss(logits, labels, mask, interpret=True):
    """Differentiable masked-sum cross-entropy loss (scalar).

    jax.grad-compatible wrapper around the fused kernel; the VJP reuses the
    dlogits the forward kernel already computed.
    """
    _, loss = softmax_xent(logits, labels, mask, interpret=interpret)
    return loss


def _xent_fwd(logits, labels, mask, interpret):
    dlogits, loss = softmax_xent(logits, labels, mask, interpret=interpret)
    return loss, dlogits


def _xent_bwd(interpret, dlogits, g):
    # labels/mask are int/constant inputs; only logits get a cotangent.
    return (dlogits * g, None, None)


xent_loss.defvjp(_xent_fwd, _xent_bwd)
