"""L2: JAX compute graphs that the Rust coordinator executes via PJRT.

Every public function here is an AOT entry point lowered by aot.py to
artifacts/<name>.hlo.txt.  They call the L1 Pallas kernels (interpret=True)
so the kernels lower into the same HLO module; Python never runs at
request time.

Entry points (chunk+mask convention — see DESIGN.md §1):
  linreg_grad_entry   (w, x, y, mask)          -> (grad_sum, loss_sum)
  logreg_grad_entry   (w, x, labels, mask)     -> (grad_sum, loss_sum)
  dual_update_entry   (z, beta, radius)        -> (w,)
  mix_entry           (p, m)                   -> (m_next,)
  transformer_grad_entry (params, tokens, mask) -> (grad, loss_sum, count)
  transformer_init    — build the flat init params for a TransformerConfig

The transformer is a standard pre-LN GPT used by the end-to-end example:
AMB treats its flattened parameter vector exactly like the regression
weight vectors (one dual variable per node), proving the coordinator is
model-agnostic.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dual_update as _pal_dual_update
from .kernels import linreg_grad as _pal_linreg_grad
from .kernels import mix as _pal_mix
from .kernels import softmax_xent as _pal_softmax_xent
from .kernels import xent_loss as _pal_xent_loss


# --------------------------------------------------------------------------
# Regression workloads (paper Sec. 6)
# --------------------------------------------------------------------------

def linreg_grad_entry(w, x, y, mask):
    """Least-squares chunk gradient.  w:(D,), x:(C,D), y:(C,), mask:(C,)."""
    grad, loss = _pal_linreg_grad(x, w, y, mask)
    return grad, loss


def logreg_grad_entry(w, x, labels, mask):
    """Multiclass logistic chunk gradient.

    w: (K, D), x: (C, D), labels: (C,) i32, mask: (C,).
    logits via plain-jnp MXU matmul; fused softmax-xent via Pallas;
    grad = dlogits^T X (second MXU matmul).
    """
    logits = x @ w.T
    dlogits, loss = _pal_softmax_xent(logits, labels, mask)
    grad = dlogits.T @ x
    return grad, loss


def dual_update_entry(z, beta, radius):
    """Paper eq. (7) primal step; z:(D,), beta:(), radius:() -> (w,)."""
    return (_pal_dual_update(z, beta, radius),)


def mix_entry(p, m):
    """One consensus round; p:(N,N), m:(N,D) -> (m',)."""
    return (_pal_mix(p, m),)


# --------------------------------------------------------------------------
# Transformer LM (end-to-end example)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Tiny pre-LN GPT.  Sized for CPU-PJRT training in the e2e example;
    scale d_model/n_layers up for a real run (DESIGN.md records the CPU
    constraint vs the ~100M target)."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq_len: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def _param_shapes(cfg: TransformerConfig):
    """Ordered (name, shape) list — the flat layout contract with Rust."""
    shapes = [
        ("tok_embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.b1", (cfg.d_ff,)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.b2", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def param_count(cfg: TransformerConfig) -> int:
    return sum(int(np.prod(s)) for _, s in _param_shapes(cfg))


def _unflatten(cfg: TransformerConfig, flat):
    params, off = {}, 0
    for name, shape in _param_shapes(cfg):
        n = int(np.prod(shape))
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def transformer_init(cfg: TransformerConfig, seed: int = 0) -> np.ndarray:
    """Flat f32 init vector (numpy; build-time only)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in _param_shapes(cfg):
        n = int(np.prod(shape))
        if name.endswith(("_g",)):
            chunks.append(np.ones(n, np.float32))
        elif name.endswith(("_b", "b1", "b2")):
            chunks.append(np.zeros(n, np.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else n
            std = 1.0 / np.sqrt(fan_in)
            chunks.append((rng.normal(0, std, n)).astype(np.float32))
    return np.concatenate(chunks)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: TransformerConfig, p, i, x):
    bsz, t, dm = x.shape
    qkv = x @ p[f"l{i}.wqkv"]                              # (B,T,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd, nh = cfg.head_dim, cfg.n_heads

    def heads(u):
        return u.reshape(bsz, t, nh, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)                 # (B,H,T,hd)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(bsz, t, dm)
    return out @ p[f"l{i}.wo"]


def _forward_logits(cfg: TransformerConfig, p, tokens):
    """tokens: (B, T) i32 -> logits (B, T, V)."""
    x = p["tok_embed"][tokens] + p["pos_embed"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        h = _layer_norm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        x = x + _attention(cfg, p, i, h)
        h = _layer_norm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        h = jax.nn.gelu(h @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
        x = x + h @ p[f"l{i}.w2"] + p[f"l{i}.b2"]
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def transformer_loss(cfg: TransformerConfig, flat, tokens, mask):
    """Masked summed next-token loss.

    flat: (P,) f32, tokens: (B, T+1) i32, mask: (B,) f32 per-sequence.
    Uses the Pallas fused softmax-xent (custom_vjp) for the LM head.
    """
    p = _unflatten(cfg, flat)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = _forward_logits(cfg, p, inp)                 # (B, T, V)
    bsz, t, v = logits.shape
    tok_mask = jnp.repeat(mask, t)                        # (B*T,)
    loss = _pal_xent_loss(
        logits.reshape(bsz * t, v), tgt.reshape(bsz * t), tok_mask
    )
    return loss


def transformer_grad_entry(cfg: TransformerConfig):
    """Build the (params, tokens, mask) -> (grad, loss_sum, count) fn.

    count = number of masked-in *tokens* (mask sum * T); the coordinator
    divides accumulated grad/loss by the global token count, mirroring the
    chunk+mask convention of the regression entries.
    """

    def fn(flat, tokens, mask):
        loss, grad = jax.value_and_grad(
            lambda f: transformer_loss(cfg, f, tokens, mask)
        )(flat)
        count = jnp.sum(mask) * (tokens.shape[1] - 1)
        return grad, loss, count

    return fn


# --------------------------------------------------------------------------
# Lowering helpers (shared with aot.py and python tests)
# --------------------------------------------------------------------------

def entry_specs(*, linreg_c, linreg_d, logreg_c, logreg_d, logreg_k,
                mix_n, mix_d, transformer_cfg: TransformerConfig,
                transformer_batch: int):
    """The full artifact set: name -> (python fn, example-arg specs).

    Shapes here are the static contract between aot.py (lowering), the
    manifest, and rust/src/runtime (loading + marshalling).
    """
    f32, i32 = jnp.float32, jnp.int32

    def s(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    cfg = transformer_cfg
    pcount = param_count(cfg)
    specs = {
        f"linreg_grad_c{linreg_c}_d{linreg_d}": (
            linreg_grad_entry,
            [s((linreg_d,)), s((linreg_c, linreg_d)), s((linreg_c,)), s((linreg_c,))],
        ),
        f"logreg_grad_c{logreg_c}_k{logreg_k}_d{logreg_d}": (
            logreg_grad_entry,
            [s((logreg_k, logreg_d)), s((logreg_c, logreg_d)),
             s((logreg_c,), i32), s((logreg_c,))],
        ),
        f"dual_update_d{linreg_d}": (
            dual_update_entry, [s((linreg_d,)), s(()), s(())],
        ),
        f"dual_update_d{logreg_k * logreg_d}": (
            dual_update_entry, [s((logreg_k * logreg_d,)), s(()), s(())],
        ),
        f"mix_n{mix_n}_d{mix_d}": (
            mix_entry, [s((mix_n, mix_n)), s((mix_n, mix_d))],
        ),
        f"transformer_grad_p{pcount}_b{transformer_batch}_t{cfg.seq_len}": (
            transformer_grad_entry(cfg),
            [s((pcount,)), s((transformer_batch, cfg.seq_len + 1), i32),
             s((transformer_batch,))],
        ),
        f"dual_update_d{pcount}": (
            dual_update_entry, [s((pcount,)), s(()), s(())],
        ),
    }
    return specs
