"""Build-time-only Python: L2 JAX model + L1 Pallas kernels + AOT lowering.

Never imported at runtime; `make artifacts` runs compile.aot once and the
Rust binary is self-contained afterwards.
"""
