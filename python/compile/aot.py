"""AOT lowering: JAX (L2+L1) -> artifacts/*.hlo.txt + manifest.json.

Runs ONCE at build time (`make artifacts`); the Rust coordinator loads the
HLO text through the PJRT CPU client and Python never appears on the
request path.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --out-dir ../artifacts            # default set
  python -m compile.aot --out-dir ../artifacts --small    # tiny test set
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {"float32": "f32", "int32": "i32"}


def _spec_json(spec) -> dict:
    return {
        "shape": list(spec.shape),
        "dtype": _DTYPE_NAMES[np.dtype(spec.dtype).name],
    }


def lower_entry(name: str, fn, arg_specs) -> tuple[str, dict]:
    """Lower one entry point; return (hlo_text, manifest entry)."""
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *arg_specs)
    if not isinstance(out_specs, (tuple, list)):
        out_specs = (out_specs,)
    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [_spec_json(s) for s in arg_specs],
        "outputs": [_spec_json(s) for s in out_specs],
    }
    return text, entry


def default_sizes(small: bool) -> dict:
    if small:
        return dict(linreg_c=32, linreg_d=64, logreg_c=16, logreg_d=24,
                    logreg_k=4, mix_n=6, mix_d=64,
                    transformer_cfg=model.TransformerConfig(
                        vocab=64, d_model=32, n_heads=2, n_layers=1,
                        d_ff=64, seq_len=16),
                    transformer_batch=2)
    return dict(linreg_c=256, linreg_d=1024, logreg_c=128, logreg_d=785,
                logreg_k=10, mix_n=10, mix_d=1024,
                transformer_cfg=model.TransformerConfig(),
                transformer_batch=8)


def build(out_dir: str, small: bool = False, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    sizes = default_sizes(small)
    specs = model.entry_specs(**sizes)
    cfg = sizes["transformer_cfg"]

    manifest = {
        "format": "hlo-text-v1",
        "small": small,
        "params": {
            "linreg_c": sizes["linreg_c"], "linreg_d": sizes["linreg_d"],
            "logreg_c": sizes["logreg_c"], "logreg_d": sizes["logreg_d"],
            "logreg_k": sizes["logreg_k"],
            "mix_n": sizes["mix_n"], "mix_d": sizes["mix_d"],
            "transformer": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
                "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
                "batch": sizes["transformer_batch"],
                "param_count": model.param_count(cfg),
            },
        },
        "entries": [],
    }

    for name, (fn, arg_specs) in specs.items():
        text, entry = lower_entry(name, fn, arg_specs)
        path = os.path.join(out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(entry)
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}")

    # Transformer init params: build-time numpy, consumed by the e2e
    # example so Rust never re-implements the init scheme.
    init = model.transformer_init(cfg, seed=0)
    init_path = os.path.join(out_dir, "transformer_init.f32.bin")
    init.tofile(init_path)
    manifest["params"]["transformer"]["init_file"] = "transformer_init.f32.bin"
    if verbose:
        print(f"  transformer init: {init.size} f32 -> {init_path}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  manifest: {len(manifest['entries'])} entries")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--small", action="store_true",
                    help="tiny shapes for fast tests")
    args = ap.parse_args()
    build(args.out_dir, small=args.small)


if __name__ == "__main__":
    main()
