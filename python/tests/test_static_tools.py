"""The static-verification tools must stay green on the live tree.

These wrap python/tools/{rustcheck,amb_lint_mirror}.py as pytest cases
so the best-effort python CI job (and any local pytest run) exercises
them alongside the kernel tests.  Stdlib-only: no jax required.
"""

import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
TOOLS = os.path.join(REPO, "python", "tools")


def _run(*argv):
    return subprocess.run(
        [sys.executable, *argv], cwd=REPO, capture_output=True, text=True, timeout=300
    )


def test_rustcheck_clean_on_live_tree():
    r = _run(os.path.join(TOOLS, "rustcheck.py"), "--repo", REPO)
    assert r.returncode == 0, f"rustcheck found issues:\n{r.stdout}{r.stderr}"
    assert "clean" in r.stdout


def test_amb_lint_mirror_selftest():
    r = _run(os.path.join(TOOLS, "amb_lint_mirror.py"), "--repo", REPO, "--selftest")
    assert r.returncode == 0, f"mirror selftest failed:\n{r.stdout}{r.stderr}"
    assert "FAIL" not in r.stdout


def test_amb_lint_mirror_live_tree_clean():
    r = _run(os.path.join(TOOLS, "amb_lint_mirror.py"), "--repo", REPO)
    assert r.returncode == 0, f"live tree has lint violations:\n{r.stdout}{r.stderr}"
    assert "0 violation(s)" in r.stdout


def test_rustcheck_detects_seeded_break(tmp_path):
    """The gate must FAIL on a seeded inconsistency, or green is meaningless
    (same philosophy as CI's amb-lint seeded-violation self-test)."""
    import shutil

    mut = tmp_path / "repo"
    shutil.copytree(
        REPO,
        mut,
        ignore=shutil.ignore_patterns(".git", "target", "__pycache__", "results"),
    )
    lib = mut / "rust" / "src" / "lib.rs"
    text = lib.read_text()
    lib.write_text(text + "\npub use crate::consensus::DoesNotExist9000;\n")
    r = _run(str(mut / "python" / "tools" / "rustcheck.py"), "--repo", str(mut))
    assert r.returncode == 1, f"rustcheck passed a seeded broken reexport:\n{r.stdout}"
    assert "DoesNotExist9000" in r.stdout
