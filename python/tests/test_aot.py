"""AOT layer: lowering produces loadable HLO text + consistent manifest."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def small_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), small=True, verbose=False)
    return str(out), manifest


def test_manifest_entry_files_exist(small_build):
    out, manifest = small_build
    assert manifest["format"] == "hlo-text-v1"
    assert len(manifest["entries"]) >= 6
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["name"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text


def test_manifest_json_roundtrip(small_build):
    out, manifest = small_build
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == manifest


def test_manifest_shapes_match_params(small_build):
    _, manifest = small_build
    p = manifest["params"]
    by_name = {e["name"]: e for e in manifest["entries"]}
    lin = by_name[f"linreg_grad_c{p['linreg_c']}_d{p['linreg_d']}"]
    assert lin["inputs"][0]["shape"] == [p["linreg_d"]]
    assert lin["inputs"][1]["shape"] == [p["linreg_c"], p["linreg_d"]]
    assert lin["outputs"][0]["shape"] == [p["linreg_d"]]
    assert lin["outputs"][1]["shape"] == []
    log = by_name[
        f"logreg_grad_c{p['logreg_c']}_k{p['logreg_k']}_d{p['logreg_d']}"]
    assert log["inputs"][2]["dtype"] == "i32"
    assert log["outputs"][0]["shape"] == [p["logreg_k"], p["logreg_d"]]


def test_transformer_init_blob(small_build):
    out, manifest = small_build
    t = manifest["params"]["transformer"]
    blob = np.fromfile(os.path.join(out, t["init_file"]), np.float32)
    assert blob.shape == (t["param_count"],)
    assert np.isfinite(blob).all()


def test_hlo_text_parses_back():
    """The emitted HLO text must parse back through XLA's text parser —
    the exact operation the Rust runtime performs via
    HloModuleProto::from_text_file.  (Executing the text requires the
    xla-crate PJRT client; that end of the bridge is pinned by
    rust/tests/pjrt_roundtrip.rs.)"""
    import jax
    from jax._src.lib import xla_client as xc
    from compile import aot as aot_mod

    lowered = jax.jit(model.linreg_grad_entry).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32),
        jax.ShapeDtypeStruct((4, 8), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    text = aot_mod.to_hlo_text(lowered)
    mod = xc._xla.hlo_module_from_text(text)
    rt = mod.as_serialized_hlo_module_proto()
    assert len(rt) > 0


def test_lowered_module_executes_via_pjrt(small_build):
    """Execute the AOT-lowered linreg module through the raw PJRT client
    (compile_and_load on the portable artifact) and check numerics against
    the oracle — proving the lowered module, not just the traced fn, is
    correct."""
    import jax
    from jax._src.lib import xla_client as xc
    from compile.kernels import ref

    _, manifest = small_build
    p = manifest["params"]
    c, d = p["linreg_c"], p["linreg_d"]

    lowered = jax.jit(model.linreg_grad_entry).lower(
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((c, d), jnp.float32),
        jax.ShapeDtypeStruct((c,), jnp.float32),
        jax.ShapeDtypeStruct((c,), jnp.float32),
    )
    mlir = str(lowered.compiler_ir("stablehlo"))
    client = xc.make_cpu_client()
    dl = xc.DeviceList(tuple(client.local_devices()))
    ser = xc._xla.mlir.serialize_portable_artifact(mlir, "0.9.0")
    exe = client.compile_and_load(ser, dl)

    rng = np.random.default_rng(0)
    w = rng.normal(size=d).astype(np.float32)
    x = rng.normal(size=(c, d)).astype(np.float32)
    y = rng.normal(size=c).astype(np.float32)
    mask = np.ones(c, np.float32)
    outs = exe.execute_sharded(
        [client.buffer_from_pyval(v) for v in (w, x, y, mask)])
    arrs = [np.asarray(b[0])
            for b in outs.disassemble_into_single_device_arrays()]
    gr, lr = ref.linreg_grad(jnp.asarray(x), jnp.asarray(w), jnp.asarray(y),
                             jnp.asarray(mask))
    np.testing.assert_allclose(arrs[0], gr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(arrs[1].reshape(()), lr, rtol=1e-3, atol=1e-3)
