"""L2 correctness: entry points, chunk+mask contract, transformer sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def _f32(rng, shape, scale=1.0):
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


# --------------------------------------------------------------------------
# regression entries
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), c=st.integers(2, 64), d=st.integers(2, 128))
def test_linreg_entry_matches_ref(seed, c, d):
    rng = np.random.default_rng(seed)
    w, x, y = _f32(rng, (d,)), _f32(rng, (c, d)), _f32(rng, (c,))
    mask = jnp.asarray((rng.random(c) < 0.6).astype(np.float32))
    g, l = model.linreg_grad_entry(w, x, y, mask)
    gr, lr = ref.linreg_grad(x, w, y, mask)
    np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(l, lr, rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), c=st.integers(2, 48),
       k=st.integers(2, 12), d=st.integers(2, 64))
def test_logreg_entry_matches_ref(seed, c, k, d):
    rng = np.random.default_rng(seed)
    w, x = _f32(rng, (k, d)), _f32(rng, (c, d))
    labels = jnp.asarray(rng.integers(0, k, c).astype(np.int32))
    mask = jnp.asarray((rng.random(c) < 0.8).astype(np.float32))
    g, l = model.logreg_grad_entry(w, x, labels, mask)
    gr, lr = ref.logreg_grad(w, x, labels, mask)
    np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(l, lr, rtol=1e-3, atol=1e-4)


def test_logreg_gradient_check():
    """Finite-difference check on the summed logreg loss."""
    rng = np.random.default_rng(0)
    k, d, c = 3, 5, 8
    w = rng.normal(size=(k, d)).astype(np.float32) * 0.3
    x = rng.normal(size=(c, d)).astype(np.float32)
    labels = rng.integers(0, k, c).astype(np.int32)
    mask = np.ones(c, np.float32)

    def loss_np(wf):
        _, l = ref.logreg_grad(jnp.asarray(wf.reshape(k, d)), jnp.asarray(x),
                               jnp.asarray(labels), jnp.asarray(mask))
        return float(l)

    g, _ = model.logreg_grad_entry(jnp.asarray(w), jnp.asarray(x),
                                   jnp.asarray(labels), jnp.asarray(mask))
    g = np.asarray(g).reshape(-1)
    wf = w.reshape(-1).astype(np.float64)
    eps = 1e-3
    for idx in rng.choice(k * d, size=6, replace=False):
        e = np.zeros_like(wf)
        e[idx] = eps
        fd = (loss_np((wf + e).astype(np.float32)) -
              loss_np((wf - e).astype(np.float32))) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2, (idx, fd, g[idx])


def test_chunked_equals_whole_batch():
    """Chunk+mask accumulation == one-shot gradient on the full batch
    (the static-shape bridge the Rust coordinator relies on)."""
    rng = np.random.default_rng(1)
    d, total, chunk = 32, 70, 16
    w = _f32(rng, (d,))
    x = _f32(rng, (total, d))
    y = _f32(rng, (total,))

    g_whole, l_whole = ref.linreg_grad(x, w, y, jnp.ones(total, jnp.float32))

    g_acc = np.zeros(d, np.float32)
    l_acc = 0.0
    for start in range(0, total, chunk):
        n = min(chunk, total - start)
        xb = np.zeros((chunk, d), np.float32)
        yb = np.zeros(chunk, np.float32)
        mb = np.zeros(chunk, np.float32)
        xb[:n] = np.asarray(x)[start:start + n]
        yb[:n] = np.asarray(y)[start:start + n]
        mb[:n] = 1.0
        g, l = model.linreg_grad_entry(w, jnp.asarray(xb), jnp.asarray(yb),
                                       jnp.asarray(mb))
        g_acc += np.asarray(g)
        l_acc += float(l)
    np.testing.assert_allclose(g_acc, g_whole, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(l_acc, l_whole, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------
# dual update / mix entries
# --------------------------------------------------------------------------

def test_dual_update_entry_shapes():
    z = jnp.arange(16, dtype=jnp.float32)
    (w,) = model.dual_update_entry(z, jnp.float32(2.0), jnp.float32(1.0))
    assert w.shape == (16,)
    assert float(jnp.linalg.norm(w)) <= 1.0 + 1e-5


def test_mix_entry_shapes():
    p = jnp.eye(4, dtype=jnp.float32)
    m = jnp.ones((4, 8), jnp.float32)
    (out,) = model.mix_entry(p, m)
    np.testing.assert_allclose(out, m)


# --------------------------------------------------------------------------
# transformer
# --------------------------------------------------------------------------

TINY = model.TransformerConfig(vocab=32, d_model=16, n_heads=2, n_layers=1,
                               d_ff=32, seq_len=8)


def test_param_count_matches_flat_init():
    flat = model.transformer_init(TINY, 0)
    assert flat.shape == (model.param_count(TINY),)
    assert np.isfinite(flat).all()


def test_transformer_loss_at_init_near_uniform():
    flat = jnp.asarray(model.transformer_init(TINY, 0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, (4, TINY.seq_len + 1)).astype(np.int32))
    mask = jnp.ones(4, jnp.float32)
    loss = model.transformer_loss(TINY, flat, toks, mask)
    per_tok = float(loss) / (4 * TINY.seq_len)
    assert abs(per_tok - np.log(TINY.vocab)) < 0.7


def test_transformer_mask_zeroes_contribution():
    flat = jnp.asarray(model.transformer_init(TINY, 0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, (4, TINY.seq_len + 1)).astype(np.int32))
    fn = model.transformer_grad_entry(TINY)
    g0, l0, c0 = fn(flat, toks, jnp.zeros(4, jnp.float32))
    assert float(l0) == 0.0 and float(c0) == 0.0
    assert float(jnp.abs(g0).max()) == 0.0


def test_transformer_grad_entry_count():
    flat = jnp.asarray(model.transformer_init(TINY, 0))
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, (4, TINY.seq_len + 1)).astype(np.int32))
    mask = jnp.asarray(np.array([1, 0, 1, 1], np.float32))
    fn = model.transformer_grad_entry(TINY)
    _, _, c = fn(flat, toks, mask)
    assert float(c) == 3 * TINY.seq_len


def test_transformer_sgd_reduces_loss():
    """A few plain-SGD steps on a repeating pattern must reduce loss —
    end-to-end sanity of value_and_grad through the Pallas head."""
    flat = jnp.asarray(model.transformer_init(TINY, 0))
    pattern = np.arange(TINY.seq_len + 1) % 7
    toks = jnp.asarray(np.tile(pattern, (4, 1)).astype(np.int32))
    mask = jnp.ones(4, jnp.float32)
    fn = jax.jit(model.transformer_grad_entry(TINY))
    losses = []
    for _ in range(30):
        g, l, c = fn(flat, toks, mask)
        losses.append(float(l) / float(c))
        flat = flat - 0.5 * g / float(c)
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_transformer_causality():
    """Changing a future token must not affect earlier logits."""
    flat = jnp.asarray(model.transformer_init(TINY, 0))
    p = model._unflatten(TINY, flat)
    rng = np.random.default_rng(5)
    toks = rng.integers(0, TINY.vocab, (1, TINY.seq_len)).astype(np.int32)
    la = model._forward_logits(TINY, p, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % TINY.vocab
    lb = model._forward_logits(TINY, p, jnp.asarray(toks2))
    np.testing.assert_allclose(la[0, :-1], lb[0, :-1], rtol=1e-4, atol=1e-5)
    assert not np.allclose(la[0, -1], lb[0, -1])
