"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).

hypothesis sweeps shapes, masks, scales and seeds; these are the CORE
correctness signal for the compute layer (the Rust integration tests then
pin the PJRT-loaded artifacts against the same numbers).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dual_update,
    linreg_grad,
    mix,
    ref,
    softmax_xent,
    xent_loss,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _f32(rng, shape, scale=1.0):
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


def _mask(rng, n, p):
    m = (rng.random(n) < p).astype(np.float32)
    return jnp.asarray(m)


# --------------------------------------------------------------------------
# linreg_grad
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    c=st.integers(1, 96),
    d=st.integers(1, 300),
    block_d=st.sampled_from([16, 64, 256]),
    pmask=st.floats(0.0, 1.0),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linreg_grad_matches_ref(c, d, block_d, pmask, scale, seed):
    rng = np.random.default_rng(seed)
    x = _f32(rng, (c, d), scale)
    w = _f32(rng, (d,))
    y = _f32(rng, (c,), scale)
    mask = _mask(rng, c, pmask)
    g, l = linreg_grad(x, w, y, mask, block_d=block_d)
    gr, lr = ref.linreg_grad(x, w, y, mask)
    np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-2 * scale * scale)
    np.testing.assert_allclose(l, lr, rtol=1e-3, atol=1e-2 * scale * scale)


def test_linreg_grad_zero_mask_is_zero():
    rng = np.random.default_rng(0)
    x, w, y = _f32(rng, (8, 16)), _f32(rng, (16,)), _f32(rng, (8,))
    g, l = linreg_grad(x, w, y, jnp.zeros(8, jnp.float32))
    assert float(jnp.abs(g).max()) == 0.0
    assert float(l) == 0.0


def test_linreg_grad_mask_linearity():
    """sum over two disjoint masks == full-mask sum (chunk+mask contract)."""
    rng = np.random.default_rng(1)
    x, w, y = _f32(rng, (32, 48)), _f32(rng, (48,)), _f32(rng, (32,))
    m = np.zeros(32, np.float32)
    m[:20] = 1
    m1, m2 = jnp.asarray(m), jnp.asarray(1 - m)
    g1, l1 = linreg_grad(x, w, y, m1)
    g2, l2 = linreg_grad(x, w, y, m2)
    g, l = linreg_grad(x, w, y, jnp.ones(32, jnp.float32))
    np.testing.assert_allclose(g1 + g2, g, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(l1 + l2, l, rtol=1e-4, atol=1e-3)


def test_linreg_grad_at_solution_is_zero():
    rng = np.random.default_rng(2)
    x, w = _f32(rng, (16, 8)), _f32(rng, (8,))
    y = x @ w
    g, l = linreg_grad(x, w, y, jnp.ones(16, jnp.float32))
    np.testing.assert_allclose(g, np.zeros(8), atol=1e-4)
    assert float(l) < 1e-6


# --------------------------------------------------------------------------
# softmax_xent
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 80),
    k=st.integers(2, 32),
    block_b=st.sampled_from([8, 32, 128]),
    pmask=st.floats(0.0, 1.0),
    scale=st.sampled_from([0.5, 3.0, 20.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(b, k, block_b, pmask, scale, seed):
    rng = np.random.default_rng(seed)
    logits = _f32(rng, (b, k), scale)
    labels = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
    mask = _mask(rng, b, pmask)
    dl, lo = softmax_xent(logits, labels, mask, block_b=block_b)
    dlr, lor = ref.softmax_xent(logits, labels, mask)
    np.testing.assert_allclose(dl, dlr, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(lo, lor, rtol=1e-3, atol=1e-4)


def test_softmax_xent_rows_sum_to_zero():
    """Each unmasked dlogits row sums to 0 (softmax minus one-hot)."""
    rng = np.random.default_rng(3)
    logits = _f32(rng, (24, 10), 5.0)
    labels = jnp.asarray(rng.integers(0, 10, 24).astype(np.int32))
    dl, _ = softmax_xent(logits, labels, jnp.ones(24, jnp.float32))
    np.testing.assert_allclose(jnp.sum(dl, axis=-1), np.zeros(24), atol=1e-5)


def test_softmax_xent_loss_nonnegative():
    rng = np.random.default_rng(4)
    logits = _f32(rng, (16, 7), 2.0)
    labels = jnp.asarray(rng.integers(0, 7, 16).astype(np.int32))
    _, lo = softmax_xent(logits, labels, jnp.ones(16, jnp.float32))
    assert float(lo) >= 0.0


def test_softmax_xent_extreme_logits_stable():
    """Large logits must not overflow (max-subtraction in kernel)."""
    logits = jnp.asarray(np.array([[1e4, 0.0, -1e4]] * 8, np.float32))
    labels = jnp.zeros(8, jnp.int32)
    dl, lo = softmax_xent(logits, labels, jnp.ones(8, jnp.float32))
    assert np.isfinite(np.asarray(dl)).all() and np.isfinite(float(lo))
    assert float(lo) < 1e-3  # correct class dominates -> ~0 loss


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_xent_loss_vjp_matches_autodiff_of_ref(seed):
    """custom_vjp wrapper == jax.grad of the pure-jnp loss."""
    import jax

    rng = np.random.default_rng(seed)
    logits = _f32(rng, (12, 6), 2.0)
    labels = jnp.asarray(rng.integers(0, 6, 12).astype(np.int32))
    mask = _mask(rng, 12, 0.7)

    def ref_loss(z):
        _, l = ref.softmax_xent(z, labels, mask)
        return l

    g_kernel = jax.grad(lambda z: xent_loss(z, labels, mask))(logits)
    g_ref = jax.grad(ref_loss)(logits)
    np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-3, atol=1e-5)


# --------------------------------------------------------------------------
# dual_update
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    d=st.integers(1, 2048),
    beta=st.floats(0.1, 100.0),
    radius=st.floats(0.01, 50.0),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dual_update_matches_ref(d, beta, radius, scale, seed):
    rng = np.random.default_rng(seed)
    z = _f32(rng, (d,), scale)
    w = dual_update(z, jnp.float32(beta), jnp.float32(radius))
    wr = ref.dual_update(z, jnp.float32(beta), jnp.float32(radius))
    np.testing.assert_allclose(w, wr, rtol=1e-3, atol=1e-6)


@settings(**SETTINGS)
@given(
    d=st.integers(1, 512),
    beta=st.floats(0.1, 10.0),
    radius=st.floats(0.01, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_dual_update_feasible(d, beta, radius, seed):
    """Output always inside the L2 ball (the paper's compact W)."""
    rng = np.random.default_rng(seed)
    z = _f32(rng, (d,), 10.0)
    w = dual_update(z, jnp.float32(beta), jnp.float32(radius))
    assert float(jnp.linalg.norm(w)) <= radius * (1 + 1e-5)


def test_dual_update_interior_exact():
    """When -z/beta is inside the ball it must be returned exactly."""
    z = jnp.asarray(np.array([0.3, -0.4, 0.0], np.float32))
    w = dual_update(z, jnp.float32(1.0), jnp.float32(10.0))
    np.testing.assert_allclose(w, -np.asarray(z), rtol=1e-6)


def test_dual_update_first_order_optimality():
    """w solves eq. (7): for feasible u, <u - w, z + beta*w> >= 0."""
    rng = np.random.default_rng(5)
    z = _f32(rng, (32,), 5.0)
    beta, radius = 2.0, 1.0
    w = np.asarray(dual_update(z, jnp.float32(beta), jnp.float32(radius)))
    grad = np.asarray(z) + beta * w
    for _ in range(50):
        u = rng.normal(size=32).astype(np.float32)
        u *= min(1.0, radius / np.linalg.norm(u))
        assert float((u - w) @ grad) >= -1e-3


# --------------------------------------------------------------------------
# mix
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 24),
    d=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_mix_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    p = np.abs(rng.normal(size=(n, n))).astype(np.float32)
    p = p / p.sum(axis=1, keepdims=True)
    m = _f32(rng, (n, d))
    out = mix(jnp.asarray(p), m)
    outr = ref.mix(jnp.asarray(p), m)
    np.testing.assert_allclose(out, outr, rtol=1e-3, atol=1e-4)


def test_mix_preserves_column_means():
    """Doubly-stochastic P conserves the average message (consensus
    invariant, paper Sec. 3)."""
    rng = np.random.default_rng(6)
    n, d = 8, 64
    # symmetric doubly-stochastic: I - small laplacian
    a = (rng.random((n, n)) < 0.4).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    deg = a.sum(1)
    p = np.eye(n, dtype=np.float32)
    for i in range(n):
        for j in range(n):
            if i != j and a[i, j] > 0:
                w = 1.0 / (1.0 + max(deg[i], deg[j]))
                p[i, j] = w
                p[i, i] -= w
    m = _f32(rng, (n, d))
    out = mix(jnp.asarray(p), m)
    np.testing.assert_allclose(
        jnp.mean(out, axis=0), jnp.mean(m, axis=0), rtol=1e-4, atol=1e-5
    )


def test_mix_consensus_convergence():
    """Repeated mixing converges every row to the average."""
    rng = np.random.default_rng(7)
    n, d = 6, 32
    p = np.full((n, n), 0.0, np.float32)
    for i in range(n):  # ring + self loop, metropolis
        p[i, i] = 1 / 3
        p[i, (i + 1) % n] = 1 / 3
        p[i, (i - 1) % n] = 1 / 3
    m = _f32(rng, (n, d))
    avg = np.asarray(jnp.mean(m, axis=0))
    cur = m
    for _ in range(200):
        cur = mix(jnp.asarray(p), cur)
    np.testing.assert_allclose(np.asarray(cur), np.tile(avg, (n, 1)),
                               rtol=1e-3, atol=1e-4)
