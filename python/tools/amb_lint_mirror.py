#!/usr/bin/env python3
"""amb_lint_mirror — a line-for-line Python port of the `amb-lint` static
analysis (rust/src/analysis/{lexer,mod,rules}.rs), for containers with no
Rust toolchain.

The Rust implementation is the product; this mirror exists to EXECUTE its
semantics where `cargo run --bin amb-lint` cannot.  It must track the Rust
source exactly — same token stream, same rule logic, same suppression
accounting, same render format — so that a divergence between "what the
mirror reports" and "what the fixture suite in analysis/tests.rs asserts"
is evidence of a bug in the Rust source (authored blind, Open item 0).

Usage:
    python3 python/tools/amb_lint_mirror.py [--selftest] [ROOT...]

With no roots: lints rust/src rust/tests rust/benches examples (the CI
invocation).  --selftest replays every assertion from analysis/tests.rs
(fixtures included) against this mirror plus the lexer unit tests.

Exit status: 0 clean / selftest pass, 1 violations / selftest fail,
2 I/O error.
"""

from __future__ import annotations

import os
import sys
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Lexer (port of rust/src/analysis/lexer.rs)
# ---------------------------------------------------------------------------

IDENT_K = "Ident"
LIFETIME_K = "Lifetime"
NUMBER_K = "Number"
STR_K = "Str"
CHAR_K = "Char"
PUNCT_K = "Punct"


@dataclass
class Tok:
    kind: str
    text: str
    line: int
    col: int


@dataclass
class Comment:
    text: str
    line: int


@dataclass
class Lexed:
    toks: list = field(default_factory=list)
    comments: list = field(default_factory=list)


class Lexer:
    def __init__(self, src: str):
        self.chars = list(src)
        self.i = 0
        self.line = 1
        self.col = 1

    def peek(self, ahead: int):
        j = self.i + ahead
        return self.chars[j] if j < len(self.chars) else None

    def bump(self):
        c = self.peek(0)
        if c is None:
            return None
        self.i += 1
        if c == "\n":
            self.line += 1
            self.col = 1
        else:
            self.col += 1
        return c

    def take_while(self, out: list, f):
        while True:
            c = self.peek(0)
            if c is None or not f(c):
                break
            out.append(c)
            self.bump()


def is_ident_start(c: str) -> bool:
    return c == "_" or (c.isascii() and c.isalpha())


def is_ident_continue(c: str) -> bool:
    return c == "_" or (c.isascii() and c.isalnum())


def is_string_prefix(ident: str, nxt) -> bool:
    prefix_ok = ident in ("r", "b", "c", "br", "rb", "cr", "rc")
    return prefix_ok and nxt in ('"', "#")


def raw_quote_follows(lx: Lexer, ident: str) -> bool:
    """For `r`-flavoured prefixes, `#*"` must follow — `r#foo` is a raw
    identifier, not a raw string."""
    if "r" not in ident:
        return True
    k = 0
    while lx.peek(k) == "#":
        k += 1
    return lx.peek(k) == '"'


def lex(src: str) -> Lexed:
    lx = Lexer(src)
    out = Lexed()
    while True:
        c = lx.peek(0)
        if c is None:
            break
        line, col = lx.line, lx.col
        if c.isspace():
            lx.bump()
            continue
        # Comments.
        if c == "/" and lx.peek(1) == "/":
            buf = []
            lx.take_while(buf, lambda ch: ch != "\n")
            out.comments.append(Comment("".join(buf), line))
            continue
        if c == "/" and lx.peek(1) == "*":
            buf = []
            depth = 0
            while True:
                c2 = lx.peek(0)
                if c2 is None:
                    break
                if c2 == "/" and lx.peek(1) == "*":
                    depth += 1
                    buf.append("/*")
                    lx.bump()
                    lx.bump()
                elif c2 == "*" and lx.peek(1) == "/":
                    depth -= 1
                    buf.append("*/")
                    lx.bump()
                    lx.bump()
                    if depth == 0:
                        break
                else:
                    buf.append(c2)
                    lx.bump()
            out.comments.append(Comment("".join(buf), line))
            continue
        # Plain strings.
        if c == '"':
            out.toks.append(lex_escaped_string(lx, "", line, col))
            continue
        # Lifetimes vs char literals.
        if c == "'":
            out.toks.append(lex_quote(lx, line, col))
            continue
        # Idents, which may turn out to be raw/byte-string prefixes.
        if is_ident_start(c):
            buf = []
            lx.take_while(buf, is_ident_continue)
            text = "".join(buf)
            if is_string_prefix(text, lx.peek(0)) and raw_quote_follows(lx, text):
                if "r" in text:
                    tok = lex_raw_string(lx, text, line, col)
                else:
                    lx.bump()  # opening quote
                    tok = lex_escaped_string(lx, text + '"', line, col)
                out.toks.append(tok)
            elif text == "r" and lx.peek(0) == "#" and (
                lx.peek(1) is not None and is_ident_start(lx.peek(1))
            ):
                # Raw identifier `r#foo`: one Ident token, `r#` kept in the
                # text so `r#unsafe` never matches the `unsafe` keyword.
                buf = [text, "#"]
                lx.bump()
                lx.take_while(buf, is_ident_continue)
                out.toks.append(Tok(IDENT_K, "".join(buf), line, col))
            else:
                out.toks.append(Tok(IDENT_K, text, line, col))
            continue
        # Numbers.
        if c.isascii() and c.isdigit():
            out.toks.append(lex_number(lx, line, col))
            continue
        lx.bump()
        out.toks.append(Tok(PUNCT_K, c, line, col))
    return out


def lex_escaped_string(lx: Lexer, text: str, line: int, col: int) -> Tok:
    buf = list(text)
    if not buf:
        lx.bump()
        buf.append('"')
    while True:
        c = lx.bump()
        if c is None:
            break
        buf.append(c)
        if c == "\\":
            esc = lx.bump()
            if esc is not None:
                buf.append(esc)
        elif c == '"':
            break
    return Tok(STR_K, "".join(buf), line, col)


def lex_raw_string(lx: Lexer, text: str, line: int, col: int) -> Tok:
    buf = list(text)
    hashes = 0
    while lx.peek(0) == "#":
        hashes += 1
        buf.append("#")
        lx.bump()
    if lx.peek(0) == '"':
        buf.append('"')
        lx.bump()
        while True:
            c = lx.bump()
            if c is None:
                break
            buf.append(c)
            if c == '"':
                if all(lx.peek(k) == "#" for k in range(hashes)):
                    for _ in range(hashes):
                        buf.append("#")
                        lx.bump()
                    break
    return Tok(STR_K, "".join(buf), line, col)


def lex_quote(lx: Lexer, line: int, col: int) -> Tok:
    after = lx.peek(1)
    if after is not None and is_ident_start(after):
        nxt2 = lx.peek(2)
        lifetime = nxt2 is None or nxt2 != "'"
    else:
        lifetime = False
    buf = ["'"]
    lx.bump()
    if lifetime:
        lx.take_while(buf, is_ident_continue)
        return Tok(LIFETIME_K, "".join(buf), line, col)
    while True:
        c = lx.bump()
        if c is None:
            break
        buf.append(c)
        if c == "\\":
            esc = lx.bump()
            if esc is not None:
                buf.append(esc)
        elif c == "'":
            break
    return Tok(CHAR_K, "".join(buf), line, col)


def lex_number(lx: Lexer, line: int, col: int) -> Tok:
    buf = []
    if lx.peek(0) == "0" and lx.peek(1) in ("x", "o", "b"):
        buf.append("0")
        lx.bump()
        base = lx.bump()
        if base is not None:
            buf.append(base)
        lx.take_while(buf, lambda c: c in "0123456789abcdefABCDEF_")
    else:
        lx.take_while(buf, lambda c: c.isascii() and c.isdigit() or c == "_")
        nxt1 = lx.peek(1)
        if lx.peek(0) == "." and nxt1 is not None and nxt1.isascii() and nxt1.isdigit():
            buf.append(".")
            lx.bump()
            lx.take_while(buf, lambda c: c.isascii() and c.isdigit() or c == "_")
        if lx.peek(0) in ("e", "E"):
            sign = lx.peek(1) in ("+", "-")
            digit_at = 2 if sign else 1
            d = lx.peek(digit_at)
            if d is not None and d.isascii() and d.isdigit():
                buf.append(lx.peek(0))  # keep the source's own `e`/`E`
                lx.bump()
                if sign:
                    s = lx.bump()
                    if s is not None:
                        buf.append(s)
                lx.take_while(buf, lambda c: c.isascii() and c.isdigit() or c == "_")
    lx.take_while(buf, is_ident_continue)
    return Tok(NUMBER_K, "".join(buf), line, col)


# ---------------------------------------------------------------------------
# Classification + regions + suppressions (port of analysis/mod.rs)
# ---------------------------------------------------------------------------

RULES = [
    ("D1", "wall-clock read in a deterministic module"),
    ("D2", "HashMap/HashSet iteration: order is nondeterministic (lookups are fine)"),
    ("D3", "raw Pcg64 seeding outside the namespaced tag-split helpers"),
    ("D4", "unwrap/expect/panic!/unreachable! in library code without a justification"),
    ("D5", "unsafe code (crate forbids it), or lib.rs missing #![forbid(unsafe_code)]"),
    ("D6", "#[ignore] without the golden-pin regen-helper marker"),
    ("meta", "malformed, unknown, or unused amb-lint suppression"),
]

DETERMINISTIC_MODULES = [
    "coordinator::sim",
    "consensus",
    "net",
    "fault",
    "churn",
    "optim",
    "straggler",
    "experiments",
]

WALL_CLOCK_ALLOWLIST = ["coordinator::threaded", "util::pool"]

JUSTIFICATION_REQUIRED = ["D4"]

LIB, BIN, TEST, EXAMPLE, BENCH, OTHER = "Lib", "Bin", "Test", "Example", "Bench", "Other"


@dataclass
class Diagnostic:
    path: str
    line: int
    col: int
    rule: str
    msg: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.msg}"


@dataclass
class Suppression:
    rule: str
    reason: object       # str | None
    target: object       # ("file",) | ("line", n)
    comment_line: int
    used: bool = False


@dataclass
class FileAnalysis:
    path: str
    kind: str
    module: object       # str | None ("" = lib.rs root)
    lexed: Lexed
    test_regions: list
    suppressions: list
    directive_issues: list

    def in_test_region(self, line: int) -> bool:
        return any(a <= line <= b for a, b in self.test_regions)


@dataclass
class Report:
    diagnostics: list = field(default_factory=list)
    files: int = 0
    suppressed: int = 0

    def is_clean(self) -> bool:
        return not self.diagnostics

    def render(self) -> str:
        out = "".join(d.render() + "\n" for d in self.diagnostics)
        out += (
            f"amb-lint: {len(self.diagnostics)} violation(s) across "
            f"{self.files} file(s) ({self.suppressed} suppressed)\n"
        )
        return out


def classify_path(path: str):
    comps = [c for c in path.split("/") if c and c != "."]
    src_at = None
    for idx in range(len(comps) - 1, -1, -1):
        if comps[idx] == "src":
            src_at = idx
            break
    if src_at is not None:
        rel = comps[src_at + 1:]
        if (rel and rel[0] == "bin") or rel == ["main.rs"]:
            return BIN, None
        parts = [c[:-3] if c.endswith(".rs") else c for c in rel]
        if parts and parts[-1] in ("mod", "lib"):
            parts.pop()
        return LIB, "::".join(parts)
    if "tests" in comps:
        return TEST, None
    if "examples" in comps:
        return EXAMPLE, None
    if "benches" in comps:
        return BENCH, None
    return OTHER, None


def is_deterministic_module(module: str) -> bool:
    def within(ms):
        return any(module == m or module.startswith(m + "::") for m in ms)

    return within(DETERMINISTIC_MODULES) and not within(WALL_CLOCK_ALLOWLIST)


def is_known_rule(rule: str) -> bool:
    return any(rid == rule and rid != "meta" for rid, _ in RULES)


def _is_punct(toks, i, c):
    return 0 <= i < len(toks) and toks[i].kind == PUNCT_K and toks[i].text == c


def scan_attr(toks, i):
    """Returns (index of closing `]`, attr marks a test item).  `test`
    inside a `not(...)` (e.g. `#[cfg(not(test))]`) is NOT a test marker."""
    depth = 1
    has_test = False
    has_not = False
    while i < len(toks):
        t = toks[i]
        if t.kind == PUNCT_K and t.text == "[":
            depth += 1
        elif t.kind == PUNCT_K and t.text == "]":
            depth -= 1
            if depth == 0:
                return i, has_test and not has_not
        elif t.kind == IDENT_K and t.text == "test":
            has_test = True
        elif t.kind == IDENT_K and t.text == "not":
            has_not = True
        i += 1
    return max(len(toks) - 1, 0), has_test and not has_not


def match_brace(toks, open_i):
    depth = 0
    i = open_i
    while i < len(toks):
        if toks[i].kind == PUNCT_K and toks[i].text == "{":
            depth += 1
        elif toks[i].kind == PUNCT_K and toks[i].text == "}":
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return max(len(toks) - 1, 0)


def test_regions(toks):
    out = []
    i = 0
    while i < len(toks):
        if not (_is_punct(toks, i, "#") and _is_punct(toks, i + 1, "[")):
            i += 1
            continue
        attr_end, has_test = scan_attr(toks, i + 2)
        if not has_test:
            i = attr_end + 1
            continue
        j = attr_end + 1
        while _is_punct(toks, j, "#") and _is_punct(toks, j + 1, "["):
            j = scan_attr(toks, j + 2)[0] + 1
        while j < len(toks) and not _is_punct(toks, j, "{") and not _is_punct(toks, j, ";"):
            j += 1
        if _is_punct(toks, j, "{"):
            close = match_brace(toks, j)
            out.append((toks[i].line, toks[close].line))
        elif j < len(toks):
            out.append((toks[i].line, toks[j].line))
        i = attr_end + 1
    return out


def parse_suppressions(lexed: Lexed, issues: list):
    token_lines = sorted({t.line for t in lexed.toks})
    out = []
    for c in lexed.comments:
        text = c.text
        if any(text.startswith(d) for d in ("///", "//!", "/**", "/*!")):
            continue
        marker = text.find("amb-lint:")
        if marker == -1:
            continue
        body = text[marker + len("amb-lint:"):]
        found_any = False
        pos = 0
        while True:
            rel = body.find("allow", pos)
            if rel == -1:
                break
            at = rel + len("allow")
            if body[at:].startswith("-file("):
                at += len("-file(")
                target = ("file",)
            elif body[at:].startswith("("):
                at += 1
                nxt = next((l for l in token_lines if l >= c.line), None)
                if nxt is None:
                    issues.append((c.line, "suppression below all code: nothing to target"))
                    pos = at
                    continue
                target = ("line", nxt)
            else:
                pos = at
                continue
            found_any = True
            rest = body[at:]
            rule = ""
            for ch in rest:
                if ch.isascii() and (ch.isalnum() or ch == "_"):
                    rule += ch
                else:
                    break
            cur = at + len(rule)
            while body[cur:].startswith(" "):
                cur += 1
            reason = None
            if body[cur:].startswith(","):
                cur += 1
                while body[cur:].startswith(" "):
                    cur += 1
                if body[cur:].startswith('"'):
                    cur += 1
                    end = body.find('"', cur)
                    if end == -1:
                        issues.append((c.line, "unterminated justification string"))
                        break
                    reason = body[cur:end]
                    cur = end + 1
                else:
                    issues.append((c.line, "expected a quoted justification after `,`"))
                    break
                while body[cur:].startswith(" "):
                    cur += 1
            if not body[cur:].startswith(")"):
                issues.append((c.line, f"expected `)` to close allow({rule}…)"))
                pos = cur
                continue
            cur += 1
            if not is_known_rule(rule):
                issues.append((c.line, f"unknown rule `{rule}` in amb-lint directive"))
            else:
                out.append(Suppression(rule, reason, target, c.line))
            pos = cur
        if not found_any:
            issues.append((c.line, "amb-lint marker without an allow(...) directive"))
    return out


def analyze_source(path: str, src: str) -> FileAnalysis:
    path = path.replace("\\", "/")
    kind, module = classify_path(path)
    lexed = lex(src)
    regions = test_regions(lexed.toks)
    issues = []
    sups = parse_suppressions(lexed, issues)
    return FileAnalysis(path, kind, module, lexed, regions, sups, issues)


# ---------------------------------------------------------------------------
# Rules (port of analysis/rules.rs)
# ---------------------------------------------------------------------------

HASH_ITER_METHODS = [
    "iter", "iter_mut", "keys", "values", "values_mut",
    "into_iter", "into_keys", "into_values", "drain", "retain",
]

TYPE_WRAPPERS = ["Option", "Rc", "Arc", "RefCell", "Mutex", "RwLock", "Box", "Cell", "mut", "dyn"]


def _ident(toks, i):
    if 0 <= i < len(toks) and toks[i].kind == IDENT_K:
        return toks[i].text
    return None


def _diag(fa, t, rule, msg):
    return Diagnostic(fa.path, t.line, t.col, rule, msg)


def hash_aliases(files):
    out = set()
    for fa in files:
        toks = fa.lexed.toks
        for i in range(len(toks)):
            if _ident(toks, i) != "type":
                continue
            name = _ident(toks, i + 1)
            if name is None or not _is_punct(toks, i + 2, "="):
                continue
            j = i + 3
            while j < len(toks) and not _is_punct(toks, j, ";"):
                if _ident(toks, j) in ("HashMap", "HashSet"):
                    out.add(name)
                    break
                j += 1
    return out


def check_file(fa, aliases):
    out = []
    if fa.kind == LIB:
        d1_wall_clock(fa, out)
        d2_hash_iteration(fa, aliases, out)
        d3_rng_discipline(fa, out)
        d4_panic_audit(fa, out)
        d5_unsafe(fa, out)
        d6_ignore_audit(fa, out)
    elif fa.kind == BIN:
        d2_hash_iteration(fa, aliases, out)
        d3_rng_discipline(fa, out)
        d4_panic_audit(fa, out)
        d5_unsafe(fa, out)
        d6_ignore_audit(fa, out)
    else:
        d2_hash_iteration(fa, aliases, out)
        d5_unsafe(fa, out)
        d6_ignore_audit(fa, out)
    return out


def d1_wall_clock(fa, out):
    module = fa.module
    if module is None or not is_deterministic_module(module):
        return
    toks = fa.lexed.toks
    for i in range(len(toks)):
        name = _ident(toks, i)
        if name is None:
            continue
        flagged = None
        if name in ("SystemTime", "available_parallelism"):
            flagged = name
        elif name == "Instant":
            if (_is_punct(toks, i + 1, ":") and _is_punct(toks, i + 2, ":")
                    and _ident(toks, i + 3) == "now"):
                flagged = "Instant::now"
        if flagged is not None:
            out.append(_diag(
                fa, toks[i], "D1",
                f"wall-clock source `{flagged}` in deterministic module `{module}`"))


def type_is_hash(toks, start, aliases):
    j = start
    limit = min(len(toks), start + 24)
    while j < limit:
        t = toks[j]
        if t.kind == PUNCT_K and t.text in ("&", "<"):
            j += 1
        elif t.kind == LIFETIME_K:
            j += 1
        elif t.kind == IDENT_K:
            name = t.text
            if name in ("HashMap", "HashSet") or name in aliases:
                return True
            if name in TYPE_WRAPPERS:
                j += 1
            elif _is_punct(toks, j + 1, ":") and _is_punct(toks, j + 2, ":"):
                j += 3
            else:
                return False
        else:
            return False
    return False


def hash_names(toks, aliases):
    names = set()
    for i in range(len(toks)):
        name = _ident(toks, i)
        if name is not None:
            if (_is_punct(toks, i + 1, ":") and not _is_punct(toks, i + 2, ":")
                    and not _is_punct(toks, i - 1, ":")
                    and type_is_hash(toks, i + 2, aliases)):
                names.add(name)
        if _ident(toks, i) == "let":
            j = i + 1
            if _ident(toks, j) == "mut":
                j += 1
            nm = _ident(toks, j)
            if nm is None:
                continue
            if not _is_punct(toks, j + 1, "=") or _is_punct(toks, j + 2, "="):
                continue
            k = j + 2
            limit = min(len(toks), k + 16)
            while (k < limit and not _is_punct(toks, k, "(")
                   and not _is_punct(toks, k, ";") and not _is_punct(toks, k, "[")):
                tid = _ident(toks, k)
                if tid is not None and (tid in ("HashMap", "HashSet") or tid in aliases):
                    names.add(nm)
                    break
                k += 1
    return names


def d2_hash_iteration(fa, aliases, out):
    toks = fa.lexed.toks
    names = hash_names(toks, aliases)
    if not names:
        return
    for i in range(len(toks)):
        m = _ident(toks, i)
        if m is not None:
            call = _is_punct(toks, i + 1, "(") and _is_punct(toks, i - 1, ".")
            if call and m in HASH_ITER_METHODS:
                recv = _ident(toks, i - 2)
                if recv is not None and recv in names:
                    out.append(_diag(
                        fa, toks[i], "D2",
                        f"`{recv}.{m}()` iterates a hash container: order is random"))
        if _ident(toks, i) == "for":
            limit = min(len(toks), i + 24)
            for j in range(i + 1, limit):
                if _ident(toks, j) != "in":
                    continue
                k = j + 1
                if _is_punct(toks, k, "&"):
                    k += 1
                if _ident(toks, k) == "mut":
                    k += 1
                nm = _ident(toks, k)
                if nm is not None and nm in names and _is_punct(toks, k + 1, "{"):
                    out.append(_diag(
                        fa, toks[k], "D2",
                        f"`for … in {nm}` iterates a hash container: order is random"))
                break


def d3_rng_discipline(fa, out):
    if fa.module == "util::rng":
        return
    toks = fa.lexed.toks
    for i in range(len(toks)):
        if (_ident(toks, i) != "Pcg64" or not _is_punct(toks, i + 1, ":")
                or not _is_punct(toks, i + 2, ":") or _ident(toks, i + 3) != "new"
                or not _is_punct(toks, i + 4, "(")):
            continue
        if fa.in_test_region(toks[i].line):
            continue
        depth = 0
        j = i + 4
        namespaced = False
        while j < len(toks):
            if _is_punct(toks, j, "("):
                depth += 1
            elif _is_punct(toks, j, ")"):
                depth -= 1
                if depth == 0:
                    break
            elif _is_punct(toks, j, "^"):
                namespaced = True
            j += 1
        if _is_punct(toks, j + 1, ".") and _ident(toks, j + 2) == "split":
            namespaced = True
        if not namespaced:
            out.append(_diag(
                fa, toks[i], "D3",
                "raw `Pcg64::new(seed)`: tag-split it (`.split(NS)`) or xor a "
                "namespace constant"))


def d4_panic_audit(fa, out):
    toks = fa.lexed.toks
    for i in range(len(toks)):
        name = _ident(toks, i)
        if name is None:
            continue
        if fa.in_test_region(toks[i].line):
            continue
        method = _is_punct(toks, i + 1, "(") and _is_punct(toks, i - 1, ".")
        if name in ("unwrap", "expect") and method:
            what = f".{name}()"
        elif name in ("panic", "unreachable") and _is_punct(toks, i + 1, "!"):
            what = f"{name}!"
        else:
            continue
        out.append(_diag(
            fa, toks[i], "D4",
            f"`{what}` in library code: route a Result or justify the panic path"))


def d5_unsafe(fa, out):
    toks = fa.lexed.toks
    for t in toks:
        if t.kind == IDENT_K and t.text == "unsafe":
            out.append(_diag(fa, t, "D5", "`unsafe` token: the crate forbids unsafe code"))
    if fa.kind == LIB and fa.module == "":
        found = False
        for i in range(len(toks)):
            if (_is_punct(toks, i, "#") and _is_punct(toks, i + 1, "!")
                    and _is_punct(toks, i + 2, "[") and _ident(toks, i + 3) == "forbid"
                    and _is_punct(toks, i + 4, "(")
                    and _ident(toks, i + 5) == "unsafe_code"):
                found = True
                break
        if not found:
            out.append(Diagnostic(
                fa.path, 1, 1, "D5", "lib.rs is missing `#![forbid(unsafe_code)]`"))


def d6_ignore_audit(fa, out):
    toks = fa.lexed.toks
    for i in range(len(toks)):
        attr = (_is_punct(toks, i, "#") and _is_punct(toks, i + 1, "[")
                and _ident(toks, i + 2) == "ignore")
        if not attr:
            continue
        ok = (_is_punct(toks, i + 3, "=")
              and i + 4 < len(toks) and toks[i + 4].kind == STR_K
              and toks[i + 4].text.startswith('"regen helper'))
        if not ok:
            out.append(_diag(
                fa, toks[i + 2], "D6",
                "`#[ignore]` without the `regen helper` marker hides a test from the suite"))


# ---------------------------------------------------------------------------
# Driver (port of lint_sources / apply_suppressions / lint_tree)
# ---------------------------------------------------------------------------


def lint_sources(files):
    analyses = [analyze_source(p, s) for p, s in files]
    aliases = hash_aliases(analyses)
    report = Report(files=len(analyses))
    for fa in analyses:
        raw = check_file(fa, aliases)
        apply_suppressions(fa, raw, report)
    report.diagnostics.sort(key=lambda d: (d.path, d.line, d.col, d.rule))
    return report


def apply_suppressions(fa, raw, report):
    for line, msg in fa.directive_issues:
        report.diagnostics.append(Diagnostic(fa.path, line, 1, "meta", msg))
    for d in raw:
        hit = None
        for s in fa.suppressions:
            if s.rule != d.rule:
                continue
            if s.target == ("file",) or s.target == ("line", d.line):
                hit = s
                break
        if hit is not None:
            hit.used = True
            if d.rule in JUSTIFICATION_REQUIRED and hit.reason is None:
                d.msg += " (suppression present but missing the justification string)"
                report.diagnostics.append(d)
            else:
                report.suppressed += 1
        else:
            report.diagnostics.append(d)
    for s in fa.suppressions:
        if not s.used:
            report.diagnostics.append(Diagnostic(
                fa.path, s.comment_line, 1, "meta",
                f"unused amb-lint suppression for {s.rule}: nothing fires it"))


SKIP_DIRS = ["fixtures", "golden", "vendor", "target"]


def collect_rs_files(root, out):
    if os.path.isfile(root):
        if root.endswith(".rs"):
            out.append(root)
        return
    entries = sorted(os.listdir(root))
    for name in entries:
        p = os.path.join(root, name)
        if os.path.isdir(p):
            if name in SKIP_DIRS or name.startswith("."):
                continue
            collect_rs_files(p, out)
        elif name.endswith(".rs"):
            out.append(p)


def lint_tree(roots):
    paths = []
    for root in roots:
        if not os.path.exists(root):
            raise OSError(f"amb-lint: cannot stat {root}")
        collect_rs_files(root, paths)
    files = []
    for p in paths:
        with open(p, encoding="utf-8") as f:
            files.append((p, f.read()))
    return lint_sources(files)


# ---------------------------------------------------------------------------
# Self-test: replay rust/src/analysis/tests.rs + lexer.rs unit tests.
# ---------------------------------------------------------------------------

FAILURES = []


def check(cond, label, detail=""):
    if not cond:
        FAILURES.append(f"{label}: {detail}")
        print(f"FAIL {label}: {detail}")
    else:
        print(f"ok   {label}")


def selftest(repo_root):
    fx = os.path.join(repo_root, "rust/src/analysis/fixtures")

    def fixture(name):
        with open(os.path.join(fx, name), encoding="utf-8") as f:
            return f.read()

    def lint_at(path, src):
        return lint_sources([(path, src)])

    def fired(r):
        return [d.rule for d in r.diagnostics]

    # ----- lexer unit tests (lexer.rs #[cfg(test)] mod) -----
    src = (
        "\n            // unsafe in a line comment\n"
        "            /* unsafe in /* a nested */ block */\n"
        '            let a = "unsafe in a string";\n'
        '            let b = r#"unsafe in a raw string"#;\n'
        "            let c = 'u';\n        "
    )
    lxd = lex(src)
    ids = [t.text for t in lxd.toks if t.kind == IDENT_K]
    check("unsafe" not in ids and ids == ["let", "a", "let", "b", "let", "c"]
          and len(lxd.comments) == 2, "lexer.comments_and_strings_hide_code_words", str(ids))

    toks = lex("fn f<'a>(x: &'a str) { 'outer: loop { break 'outer; } }").toks
    lts = [t.text for t in toks if t.kind == LIFETIME_K]
    check(lts == ["'a", "'a", "'outer", "'outer"], "lexer.lifetimes", str(lts))

    toks = lex(r"let q = '\''; let n = '\n'; let p = 'x';").toks
    check(sum(1 for t in toks if t.kind == CHAR_K) == 3, "lexer.char_escapes")

    toks = lex("for i in 1..n { let t = 0xFA17_1055 ^ 1.5e-3f64; }").toks
    nums = [t.text for t in toks if t.kind == NUMBER_K]
    dots = sum(1 for t in toks if t.kind == PUNCT_K and t.text == ".")
    check(nums == ["1", "0xFA17_1055", "1.5e-3f64"] and dots == 2,
          "lexer.ranges_and_hex", str(nums))

    toks = lex("ab cd\n  ef").toks
    check([(t.line, t.col) for t in toks] == [(1, 1), (1, 4), (2, 3)], "lexer.spans")

    toks = lex("let x = 1.max(2);").toks
    check((toks[3].text, toks[4].text, toks[5].text) == ("1", ".", "max"),
          "lexer.method_after_int")

    # ----- regression tests for the three PR-10 lexer/rule fixes -----
    toks = lex("let t = 2E10 + 1.5E-3;").toks
    nums = [t.text for t in toks if t.kind == NUMBER_K]
    check(nums == ["2E10", "1.5E-3"], "lexer.uppercase_exponent_text", str(nums))

    toks = lex("let r#type = r#fn + 1; let s = r#\"raw\"#;").toks
    ids = [t.text for t in toks if t.kind == IDENT_K]
    strs = [t.text for t in toks if t.kind == STR_K]
    check("r#type" in ids and "r#fn" in ids and strs == ['r#"raw"#'],
          "lexer.raw_idents_vs_raw_strings", f"{ids} {strs}")

    not_test = ("#[cfg(not(test))]\nmod shim {\n"
                "    pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n}\n")
    r = lint_at("rust/src/consensus/fix.rs", not_test)
    check(fired(r) == ["D4"], "rules.cfg_not_test_is_not_a_test_region", r.render())

    # ----- tests.rs fixture assertions -----
    r = lint_at("rust/src/consensus/fix.rs", fixture("d1_wall_clock.rs"))
    inst = [d for d in r.diagnostics if "Instant::now" in d.msg]
    check(fired(r) == ["D1"] * 5 and inst and (inst[0].line, inst[0].col) == (5, 14),
          "d1_fires_in_deterministic_module", r.render())
    for p in ("rust/src/coordinator/threaded/fix.rs", "rust/src/util/pool/fix.rs"):
        r = lint_at(p, fixture("d1_wall_clock.rs"))
        check(r.is_clean(), f"d1_allowlist:{p}", r.render())
    r = lint_at("rust/src/consensus/fix.rs", fixture("d1_wall_clock_ok.rs"))
    check(r.is_clean() and r.suppressed == 2, "d1_suppressed_twin", r.render())

    r = lint_at("rust/src/consensus/fix.rs", fixture("d2_hash_iter.rs"))
    lines = [d.line for d in r.diagnostics]
    check(fired(r) == ["D2"] * 3 and lines == [5, 9, 18], "d2_fires", r.render())
    r = lint_at("rust/src/consensus/fix.rs", fixture("d2_hash_iter_ok.rs"))
    check(r.is_clean(), "d2_ok_twin", r.render())

    alias = "pub type DropMask = std::collections::HashSet<u64>;\n"
    user = "pub fn live(mask: &DropMask) -> usize { mask.iter().count() }\n"
    r = lint_sources([("rust/src/fault/fix.rs", alias), ("rust/src/net/fix.rs", user)])
    check(fired(r) == ["D2"] and r.diagnostics[0].path == "rust/src/net/fix.rs",
          "d2_alias_cross_file", r.render())

    r = lint_at("rust/src/consensus/fix.rs", fixture("d3_rng.rs"))
    check(fired(r) == ["D3"], "d3_fires", r.render())
    r = lint_at("rust/src/consensus/fix.rs", fixture("d3_rng_ok.rs"))
    check(r.is_clean() and r.suppressed == 1, "d3_ok_twin", r.render())

    src = ("#[cfg(test)]\nmod tests {\n    use crate::util::rng::Pcg64;\n    #[test]\n    "
           "fn draws() { let mut r = Pcg64::new(7); assert!(r.f64() < 1.0); }\n}\n")
    r = lint_at("rust/src/consensus/fix.rs", src)
    check(r.is_clean(), "d3_test_region_exempt", r.render())
    r = lint_at("rust/tests/fix.rs", fixture("d3_rng.rs"))
    check(r.is_clean(), "d3_test_source_exempt", r.render())

    r = lint_at("rust/src/consensus/fix.rs", fixture("d4_panics.rs"))
    msgs = "".join(d.msg for d in r.diagnostics)
    check(fired(r) == ["D4"] * 4
          and all(f in msgs for f in (".unwrap()", ".expect()", "panic!", "unreachable!")),
          "d4_fires", r.render())
    r = lint_at("rust/src/consensus/fix.rs", fixture("d4_panics_ok.rs"))
    check(r.is_clean() and r.suppressed == 2, "d4_ok_twin", r.render())
    r = lint_at("rust/src/consensus/fix.rs", fixture("d4_bare_allow.rs"))
    check(fired(r) == ["D4"] and "missing the justification" in r.diagnostics[0].msg,
          "d4_bare_allow", r.render())
    for p in ("rust/tests/fix.rs", "examples/fix.rs", "rust/benches/fix.rs"):
        r = lint_at(p, fixture("d4_panics.rs"))
        check(r.is_clean(), f"d4_exempt:{p}", r.render())

    r = lint_at("scratch/seeded.rs", fixture("d5_unsafe.rs"))
    check(fired(r) == ["D5"], "d5_fires", r.render())
    r = lint_at("scratch/seeded.rs", fixture("d5_unsafe_ok.rs"))
    check(r.is_clean(), "d5_ok_twin", r.render())
    r = lint_at("rust/src/lib.rs", "pub mod consensus;\n")
    check(fired(r) == ["D5"] and "forbid(unsafe_code)" in r.diagnostics[0].msg,
          "d5_lib_forbid_missing", r.render())
    r = lint_at("rust/src/lib.rs", "#![forbid(unsafe_code)]\npub mod consensus;\n")
    check(r.is_clean(), "d5_lib_forbid_present", r.render())

    r = lint_at("rust/tests/fix.rs", fixture("d6_ignore.rs"))
    check(fired(r) == ["D6"], "d6_fires", r.render())
    r = lint_at("rust/tests/fix.rs", fixture("d6_ignore_ok.rs"))
    check(r.is_clean(), "d6_ok_twin", r.render())

    r = lint_at("rust/src/consensus/fix.rs", fixture("meta_bad.rs"))
    msgs = "".join(d.msg for d in r.diagnostics)
    check(fired(r) == ["meta", "meta"] and "unknown rule `D9`" in msgs
          and "unused amb-lint suppression for D4" in msgs, "meta_bad", r.render())

    src = '/// Use `// amb-lint: allow(D4, "why")` at the site.\npub fn f() {}\n'
    r = lint_at("rust/src/consensus/fix.rs", src)
    check(r.is_clean(), "doc_comments_not_directives", r.render())

    # ----- lints_clean_on_live_tree -----
    roots = [os.path.join(repo_root, p)
             for p in ("rust/src", "rust/tests", "rust/benches", "examples")]
    report = lint_tree(roots)
    check(report.files > 50, "live_tree_walker_found_files", f"only {report.files}")
    check(report.is_clean(), "lints_clean_on_live_tree", "\n" + report.render())
    print(f"live tree: {report.files} files, {report.suppressed} suppressions in use")

    return 1 if FAILURES else 0


def main():
    args = sys.argv[1:]
    repo_root = os.getcwd()
    if args and args[0] == "--repo":
        repo_root = args[1]
        args = args[2:]
    if args and args[0] == "--selftest":
        return selftest(repo_root)
    roots = args or [
        p for p in ("rust/src", "rust/tests", "rust/benches", "examples")
        if os.path.exists(os.path.join(repo_root, p))
    ]
    roots = [os.path.join(repo_root, r) for r in roots]
    if not roots:
        print("amb-lint-mirror: no roots to lint", file=sys.stderr)
        return 2
    try:
        report = lint_tree(roots)
    except OSError as e:
        print(f"amb-lint-mirror: {e}", file=sys.stderr)
        return 2
    sys.stdout.write(report.render())
    return 0 if report.is_clean() else 1


if __name__ == "__main__":
    sys.exit(main())
