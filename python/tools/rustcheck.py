#!/usr/bin/env python3
"""rustcheck — a dependency-free cross-file consistency checker for the
anytime-mb Rust tree, for containers with no Rust toolchain.

This is NOT a compiler and proves far less than `cargo check`: it cannot
type-check, borrow-check, or resolve trait-method calls.  What it CAN do
— entirely statically, with no dependencies beyond the Python stdlib —
is catch the cross-file fallout that blind authoring actually produces:

  * `mod` declarations with no backing file, files not reachable from
    any `mod` declaration;
  * `use crate::…` / `use anytime_mb::…` / in-body absolute paths that
    do not resolve to a defined item (typo'd module or item names,
    items that were renamed in one file but not the other);
  * cross-module references to items that exist but are private;
  * struct literals / struct patterns naming fields the struct does not
    have, or (when no `..` rest pattern is used) missing fields;
  * `Enum::Variant` references to variants that do not exist;
  * crate-internal free/associated function calls with the wrong arity;
  * `impl Trait for Type` blocks missing required (no-default) methods.

Usage:
    python3 python/tools/rustcheck.py [--repo ROOT]

Exit status: 0 clean, 1 findings, 2 I/O error.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Source masking: blank out comments and string/char literals (preserving
# newlines and byte offsets) so that downstream regexes only ever see code.
# Mirrors the semantics of rust/src/analysis/lexer.rs.
# ---------------------------------------------------------------------------


def mask_source(src: str) -> str:
    out = list(src)
    i, n = 0, len(src)

    def blank(a: int, b: int) -> None:
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = src.find("\n", i)
            j = n if j == -1 else j
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c in "\"'" or (
            c in "rb" and _string_start(src, i)
        ):
            j, is_str = _scan_literal(src, i)
            if is_str:
                # keep the delimiters so token boundaries survive
                blank(i + 1, j - 1 if j - 1 > i + 1 else i + 1)
                i = j
            else:
                i += 1
        else:
            i += 1
    return "".join(out)


def _string_start(src: str, i: int) -> bool:
    """True when src[i] begins a raw/byte string or byte char literal."""
    m = re.match(r'(?:r#*"|rb#*"|br#*"|b"|b\')', src[i:])
    if not m:
        return False
    # not part of an identifier like `for` / `crb"...`? identifiers can't
    # contain quotes, but a preceding ident char means `r`/`b` belong to it.
    if i > 0 and (src[i - 1].isalnum() or src[i - 1] == "_"):
        return False
    return True


def _scan_literal(src: str, i: int):
    """Scan a string/char literal starting at i. Returns (end_index, is_literal).

    For `'` distinguishes char literals from lifetimes: a lifetime is `'`
    followed by an identifier NOT closed by another `'`.
    """
    n = len(src)
    c = src[i]
    if c == "'":
        # char literal forms: 'x', '\n', '\u{..}', '\'' — else lifetime
        m = re.match(r"'(?:\\.[^']*|\\u\{[0-9a-fA-F_]+\}|[^\\'])'", src[i:])
        if m:
            return i + m.end(), True
        return i + 1, False
    if c == '"':
        j = i + 1
        while j < n:
            if src[j] == "\\":
                j += 2
            elif src[j] == '"':
                return j + 1, True
            else:
                j += 1
        return n, True
    # raw / byte strings
    m = re.match(r'(?:rb|br|r|b)(#*)"', src[i:])
    if m:
        hashes = m.group(1)
        if 'r' in m.group(0):
            close = '"' + hashes
            j = src.find(close, i + m.end())
            return (n if j == -1 else j + len(close)), True
        # b"..." — escaped string
        j = i + m.end()
        while j < n:
            if src[j] == "\\":
                j += 2
            elif src[j] == '"':
                return j + 1, True
            else:
                j += 1
        return n, True
    if src.startswith("b'", i):
        m = re.match(r"b'(?:\\.|[^\\'])'", src[i:])
        if m:
            return i + m.end(), True
    return i + 1, False


def line_of(src: str, off: int) -> int:
    return src.count("\n", 0, off) + 1


# ---------------------------------------------------------------------------
# Item model
# ---------------------------------------------------------------------------


@dataclass
class Fn:
    name: str
    arity: int          # declared params, EXCLUDING self
    has_self: bool
    is_pub: bool
    variadic_like: bool  # impl Trait / generics make arity fuzzy? (kept exact)
    line: int


@dataclass
class Struct:
    name: str
    fields: dict        # name -> is_pub (empty for tuple/unit structs)
    is_tuple: bool
    is_pub: bool
    line: int


@dataclass
class Enum:
    name: str
    variants: dict      # name -> fields dict (None for tuple/unit variants)
    is_pub: bool
    line: int


@dataclass
class Trait:
    name: str
    required: list      # method names without default bodies
    provided: list
    is_pub: bool
    line: int


@dataclass
class Module:
    path: str                      # "crate::consensus::sparse"
    file: str
    submodules: dict = field(default_factory=dict)   # name -> Module
    fns: dict = field(default_factory=dict)
    structs: dict = field(default_factory=dict)
    enums: dict = field(default_factory=dict)
    traits: dict = field(default_factory=dict)
    consts: dict = field(default_factory=dict)       # name -> is_pub
    types: dict = field(default_factory=dict)        # alias -> is_pub
    macros: set = field(default_factory=set)
    reexports: dict = field(default_factory=dict)    # local name -> target path (list of segs)
    glob_reexports: list = field(default_factory=list)
    # assoc items: type name -> {method name -> Fn}
    assoc: dict = field(default_factory=dict)
    # types whose impls are macro-generated: associated items unknowable
    open_types: set = field(default_factory=set)
    # fn names defined inside macro_rules! bodies (macro-generated methods)
    macro_methods: set = field(default_factory=set)
    trait_impls: list = field(default_factory=list)  # (trait_path, type_name, methods, line)


FINDINGS = []


def finding(file: str, line: int, kind: str, msg: str) -> None:
    FINDINGS.append((file, line, kind, msg))


# ---------------------------------------------------------------------------
# Parsing one file into a Module
# ---------------------------------------------------------------------------

IDENT = r"[A-Za-z_][A-Za-z0-9_]*"

FN_RE = re.compile(
    r"^[ \t]*(pub(?:\([^)]*\))?\s+)?(?:const\s+)?(?:async\s+)?(?:unsafe\s+)?(?:extern\s+\"[^\"]*\"\s+)?fn\s+(" + IDENT + r")\s*(<)?",
    re.M,
)
STRUCT_RE = re.compile(
    r"^[ \t]*(pub(?:\([^)]*\))?\s+)?struct\s+(" + IDENT + r")", re.M
)
ENUM_RE = re.compile(r"^[ \t]*(pub(?:\([^)]*\))?\s+)?enum\s+(" + IDENT + r")", re.M)
TRAIT_RE = re.compile(r"^[ \t]*(pub(?:\([^)]*\))?\s+)?trait\s+(" + IDENT + r")", re.M)
CONST_RE = re.compile(
    r"^[ \t]*(pub(?:\([^)]*\))?\s+)?(?:const|static)\s+(" + IDENT + r")\s*:", re.M
)
TYPE_RE = re.compile(r"^[ \t]*(pub(?:\([^)]*\))?\s+)?type\s+(" + IDENT + r")\s*[=<]", re.M)
MACRO_RE = re.compile(r"^[ \t]*macro_rules!\s*(" + IDENT + r")", re.M)
MOD_DECL_RE = re.compile(r"^[ \t]*(pub(?:\([^)]*\))?\s+)?mod\s+(" + IDENT + r")\s*;", re.M)
MOD_INLINE_RE = re.compile(r"^[ \t]*(pub(?:\([^)]*\))?\s+)?mod\s+(" + IDENT + r")\s*\{", re.M)
IMPL_RE = re.compile(
    r"^[ \t]*impl(?:\s*<[^>]*>)?\s+(?:(" + IDENT + r"(?:::" + IDENT + r")*)(?:\s*<[^;{]*?>)?\s+for\s+)?("
    + IDENT + r")(?:\s*<[^;{]*?>)?\s*(?:where[^{]*)?\{",
    re.M,
)
USE_RE = re.compile(r"^[ \t]*(?:pub(?:\([^)]*\))?\s+)?use\s+([^;]+);", re.M | re.S)


def matching_brace(src: str, open_idx: int) -> int:
    """Index just past the brace matching src[open_idx] == '{'."""
    depth = 0
    for j in range(open_idx, len(src)):
        if src[j] == "{":
            depth += 1
        elif src[j] == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(src)


def split_top_commas(s: str, angles: bool = False):
    """Split on depth-0 commas.  `angles=True` additionally tracks <> as
    brackets — correct in TYPE position (fn params, enum variant fields)
    but wrong in expression position where `>` is a comparison operator.
    Depth is clamped at 0 so stray closers (`-> f64`) can't mask commas."""
    parts, cur = [], []
    depth = 0   # () [] {}
    adepth = 0  # <> when angles=True
    prev = ""
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth = max(0, depth - 1)
        elif angles and ch == "<" and prev != "<":
            adepth += 1
        elif angles and ch == ">" and prev not in "-=":
            adepth = max(0, adepth - 1)
        if ch == "," and depth == 0 and adepth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
        if not ch.isspace():
            prev = ch
    if cur and "".join(cur).strip():
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def parse_fn_sig(masked: str, m) -> Fn:
    name = m.group(2)
    is_pub = bool(m.group(1))
    # find the param list opening paren after any generics
    j = m.end() - (1 if m.group(3) else 0)
    if m.group(3):  # skip generics <...> with depth tracking
        depth = 0
        while j < len(masked):
            if masked[j] == "<":
                depth += 1
            elif masked[j] == ">":
                depth -= 1
                if depth == 0:
                    j += 1
                    break
            j += 1
    p = masked.find("(", j)
    if p == -1:
        return Fn(name, 0, False, is_pub, False, line_of(masked, m.start()))
    depth, q = 0, p
    while q < len(masked):
        if masked[q] == "(":
            depth += 1
        elif masked[q] == ")":
            depth -= 1
            if depth == 0:
                break
        q += 1
    params = split_top_commas(masked[p + 1 : q], angles=True)
    has_self = bool(params) and re.search(r"\bself\b", params[0].split(":")[0] or params[0]) is not None
    arity = len(params) - (1 if has_self else 0)
    return Fn(name, arity, has_self, is_pub, False, line_of(masked, m.start()))


def parse_struct_body(masked: str, m) -> Struct:
    name, is_pub = m.group(2), bool(m.group(1))
    line = line_of(masked, m.start())
    # find what follows the name (possibly generics / where)
    j = m.end()
    # scan forward to the first of '{', '(', ';' at depth 0 of <>
    depth = 0
    while j < len(masked):
        ch = masked[j]
        if ch == "<":
            depth += 1
        elif ch == ">":
            depth = max(0, depth - 1)
        elif depth == 0 and ch in "{(;":
            break
        j += 1
    if j >= len(masked) or masked[j] == ";":
        return Struct(name, {}, False, is_pub, line)
    if masked[j] == "(":
        return Struct(name, {}, True, is_pub, line)
    end = matching_brace(masked, j)
    body = masked[j + 1 : end - 1]
    fields = {}
    for fm in re.finditer(
        r"(?:^|,)\s*(?:#\[[^\]]*\]\s*)*(pub(?:\([^)]*\))?\s+)?(" + IDENT + r")\s*:", body
    ):
        fields[fm.group(2)] = bool(fm.group(1))
    return Struct(name, fields, False, is_pub, line)


def parse_enum_body(masked: str, m) -> Enum:
    name, is_pub = m.group(2), bool(m.group(1))
    line = line_of(masked, m.start())
    j = masked.find("{", m.end())
    if j == -1:
        return Enum(name, {}, is_pub, line)
    end = matching_brace(masked, j)
    body = masked[j + 1 : end - 1]
    variants = {}
    for part in split_top_commas(body, angles=True):
        part = re.sub(r"#\[[^\]]*\]", "", part).strip()
        vm = re.match(r"(" + IDENT + r")\s*(\{|\(|=|$)", part)
        if not vm:
            continue
        vname, opener = vm.group(1), vm.group(2)
        if opener == "{":
            fb = part[part.index("{") + 1 : part.rindex("}")]
            vfields = {}
            for fm in re.finditer(r"(?:^|,)\s*(" + IDENT + r")\s*:", fb):
                vfields[fm.group(1)] = True
            variants[vname] = vfields
        else:
            variants[vname] = None
    return Enum(name, variants, is_pub, line)


def parse_trait_body(masked: str, m) -> Trait:
    name, is_pub = m.group(2), bool(m.group(1))
    line = line_of(masked, m.start())
    j = masked.find("{", m.end())
    if j == -1:
        return Trait(name, [], [], is_pub, line)
    end = matching_brace(masked, j)
    body = masked[j + 1 : end - 1]
    required, provided = [], []
    for fm in re.finditer(r"\bfn\s+(" + IDENT + r")", body):
        # look ahead from the signature for ';' vs '{' at angle/paren depth 0
        k, depth = fm.end(), 0
        while k < len(body):
            ch = body[k]
            if ch in "(<[":
                depth += 1
            elif ch in ")>]":
                depth = max(0, depth - 1)
            elif depth == 0 and ch == ";":
                required.append(fm.group(1))
                break
            elif depth == 0 and ch == "{":
                provided.append(fm.group(1))
                k = j + 1 + matching_brace(body, k) - 1
                break
            k += 1
    return Trait(name, required, provided, is_pub, line)


def parse_impl_blocks(masked: str, mod: Module) -> None:
    for m in IMPL_RE.finditer(masked):
        trait_path, type_name = m.group(1), m.group(2)
        open_idx = masked.index("{", m.start())
        end = matching_brace(masked, open_idx)
        body = masked[open_idx + 1 : end - 1]
        body_off = open_idx + 1
        methods = {}
        for fm in FN_RE.finditer(body):
            f = parse_fn_sig(body, fm)
            f = Fn(f.name, f.arity, f.has_self, f.is_pub,
                   f.variadic_like, line_of(masked, body_off + fm.start()))
            methods[f.name] = f
        # associated consts/types are addressable as Type::NAME too
        for cm in CONST_RE.finditer(body):
            methods.setdefault(
                cm.group(2),
                Fn(cm.group(2), 0, False, bool(cm.group(1)), False,
                   line_of(masked, body_off + cm.start())),
            )
        for tm in TYPE_RE.finditer(body):
            methods.setdefault(
                tm.group(2),
                Fn(tm.group(2), 0, False, bool(tm.group(1)), False,
                   line_of(masked, body_off + tm.start())),
            )
        if trait_path:
            mod.trait_impls.append(
                (trait_path, type_name, set(methods), line_of(masked, m.start()))
            )
            # trait methods are callable on the type too
            mod.assoc.setdefault(type_name, {}).update(
                {k: v for k, v in methods.items() if k not in mod.assoc.get(type_name, {})}
            )
        else:
            mod.assoc.setdefault(type_name, {}).update(methods)


def strip_inline_mod_bodies(masked: str):
    """Return masked source with inline `mod x { .. }` bodies blanked, plus
    a list of (name, is_pub, body, body_line_offset)."""
    out = masked
    inline = []
    # iterate until no inline mods remain (handles nesting by peeling outer)
    while True:
        m = MOD_INLINE_RE.search(out)
        if not m:
            break
        open_idx = out.index("{", m.start())
        end = matching_brace(out, open_idx)
        body = out[open_idx + 1 : end - 1]
        inline.append(
            (m.group(2), bool(m.group(1)), body, line_of(out, open_idx))
        )
        # blank the whole block so the outer-scope parse doesn't see it
        chunk = out[m.start() : end]
        out = out[: m.start()] + "".join(
            ch if ch == "\n" else " " for ch in chunk
        ) + out[end:]
    return out, inline


def parse_module_source(masked: str, path: str, file: str) -> Module:
    mod = Module(path=path, file=file)
    top, inline_mods = strip_inline_mod_bodies(masked)

    for m in FN_RE.finditer(top):
        # skip fns inside impl/trait bodies: detect by brace depth at match
        if brace_depth(top, m.start()) > 0:
            continue
        f = parse_fn_sig(top, m)
        mod.fns[f.name] = f
    for m in STRUCT_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        s = parse_struct_body(top, m)
        mod.structs[s.name] = s
    for m in ENUM_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        e = parse_enum_body(top, m)
        mod.enums[e.name] = e
    for m in TRAIT_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        t = parse_trait_body(top, m)
        mod.traits[t.name] = t
    for m in CONST_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        mod.consts[m.group(2)] = bool(m.group(1))
    for m in TYPE_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        mod.types[m.group(2)] = bool(m.group(1))
    for m in MACRO_RE.finditer(top):
        mod.macros.add(m.group(1))
    parse_impl_blocks(top, mod)

    # macro-generated impls: a local macro_rules! whose body contains
    # `impl` makes the associated items of the types it is invoked on
    # unknowable statically — mark those types open (skip assoc checks).
    impl_macros = set()
    for m in MACRO_RE.finditer(top):
        open_idx = top.find("{", m.end())
        if open_idx == -1:
            continue
        end = matching_brace(top, open_idx)
        body = top[open_idx:end]
        for fm in re.finditer(r"\bfn\s+([A-Za-z_][A-Za-z0-9_]*)", body):
            mod.macro_methods.add(fm.group(1))
        if re.search(r"\bimpl\b", body):
            impl_macros.add(m.group(1))
    if impl_macros:
        for im in re.finditer(
            r"\b(" + "|".join(sorted(impl_macros)) + r")!\s*[\(\[\{]([^;]*)", top
        ):
            for ident in re.findall(r"[A-Z][A-Za-z0-9_]*", im.group(2)):
                mod.open_types.add(ident)

    for m in USE_RE.finditer(top):
        if brace_depth(top, m.start()) > 0:
            continue
        register_use(mod, m.group(1))

    # inline modules become child Modules parsed from their bodies
    for name, is_pub, body, _off in inline_mods:
        child = parse_module_source(body, f"{path}::{name}", file)
        mod.submodules[name] = child
    return mod


_DEPTH_CACHE = {}


def brace_depth(masked: str, off: int) -> int:
    key = id(masked)
    hit = _DEPTH_CACHE.get(key)
    # the cache holds a strong ref to the string so id() can't be recycled
    if hit is None or hit[0] is not masked:
        depths = [0] * (len(masked) + 1)
        d = 0
        for i, ch in enumerate(masked):
            depths[i] = d
            if ch == "{":
                d += 1
            elif ch == "}":
                d = max(0, d - 1)
        depths[len(masked)] = d
        _DEPTH_CACHE[key] = (masked, depths)
        return depths[off]
    return hit[1][off]


def register_use(mod: Module, spec: str) -> None:
    spec = re.sub(r"\s+", " ", spec).strip()
    for prefix, leaves in expand_use_tree(spec):
        for leaf, alias in leaves:
            segs = prefix + ([leaf] if leaf != "self" else [])
            if leaf == "*":
                mod.glob_reexports.append(segs[:-1] if segs and segs[-1] == "*" else prefix)
                continue
            name = alias or (segs[-1] if segs else leaf)
            mod.reexports[name] = segs


def expand_use_tree(spec: str):
    """Expand `a::b::{c, d as e, f::{g}}` into (prefix, [(leaf, alias)])."""
    results = []

    def rec(prefix, s):
        s = s.strip()
        if s.startswith("{"):
            inner = s[1 : s.rindex("}")]
            for part in split_top_commas(inner):
                rec(prefix, part)
            return
        # split off the first `{` group if present
        b = s.find("{")
        if b != -1:
            head = s[:b].strip().rstrip(":")
            segs = [x for x in head.split("::") if x]
            rec(prefix + segs, s[b:])
            return
        m = re.match(r"(.+?)\s+as\s+(" + IDENT + r")$", s)
        alias = None
        if m:
            s, alias = m.group(1).strip(), m.group(2)
        segs = [x for x in s.split("::") if x]
        if not segs:
            return
        results.append((prefix + segs[:-1], [(segs[-1], alias)]))

    rec([], spec)
    return results


# ---------------------------------------------------------------------------
# Crate assembly
# ---------------------------------------------------------------------------


def load_crate(root_file: str, crate_name: str) -> Module:
    """Parse the module tree rooted at root_file (lib.rs / main.rs)."""
    seen = set()

    def load(file: str, path: str, is_root: bool = False) -> Module:
        with open(file, encoding="utf-8") as f:
            src = f.read()
        masked = mask_source(src)
        mod = parse_module_source(masked, path, file)
        base_dir = os.path.dirname(file)
        stem = os.path.splitext(os.path.basename(file))[0]
        for m in MOD_DECL_RE.finditer(masked):
            if brace_depth(masked, m.start()) > 0:
                continue
            name = m.group(2)
            if is_root or stem in ("lib", "main", "mod"):
                cand = [
                    os.path.join(base_dir, name + ".rs"),
                    os.path.join(base_dir, name, "mod.rs"),
                ]
            else:
                cand = [
                    os.path.join(base_dir, stem, name + ".rs"),
                    os.path.join(base_dir, stem, name, "mod.rs"),
                ]
            # honour #[path = "..."] attribute just above the decl
            pre = masked[: m.start()].rsplit("\n", 3)[-3:]
            pm = re.search(r'#\[path\s*=\s*"', "\n".join(pre))
            hit = next((c for c in cand if os.path.exists(c)), None)
            if pm:
                # path attr value lives in the UNMASKED source; find it
                rawpre = src[: m.start()].rsplit("\n", 3)[-3:]
                rm = re.search(r'#\[path\s*=\s*"([^"]+)"\s*\]', "\n".join(rawpre))
                if rm:
                    hit = os.path.join(base_dir, rm.group(1))
                    if not os.path.exists(hit):
                        hit = None
            if hit is None:
                finding(file, line_of(masked, m.start()), "mod-missing",
                        f"mod {name}; has no backing file (tried {cand})")
                continue
            if hit in seen:
                continue
            seen.add(hit)
            mod.submodules[name] = load(hit, f"{path}::{name}")
        return mod

    seen.add(root_file)
    root = load(root_file, crate_name, is_root=True)
    # #[macro_export] macros are addressable at the crate root regardless
    # of the module that defines them; approximate by hoisting every
    # macro_rules! name to the root namespace.
    for m in iter_modules(root):
        root.macros |= m.macros
    return root


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


class Resolver:
    def __init__(self, crates: dict):
        self.crates = crates  # name -> root Module

    def lookup_module(self, segs):
        """Resolve a module path (no item leaf)."""
        if not segs or segs[0] not in self.crates:
            return None
        mod = self.crates[segs[0]]
        for s in segs[1:]:
            nxt = mod.submodules.get(s)
            if nxt is None:
                # re-exported module?
                tgt = mod.reexports.get(s)
                if tgt is not None:
                    resolved = self.lookup_module(self.absolutize(tgt, mod))
                    if resolved is not None:
                        mod = resolved
                        continue
                return None
            mod = nxt
        return mod

    def absolutize(self, segs, ctx: Module):
        """Map crate::/self::/super:: prefixes to absolute crate paths."""
        if not segs:
            return segs
        ctx_segs = ctx.path.split("::")
        if segs[0] == "crate":
            return [ctx_segs[0]] + segs[1:]
        if segs[0] == "self":
            return ctx_segs + segs[1:]
        if segs[0] == "super":
            k = 0
            while k < len(segs) and segs[k] == "super":
                k += 1
            return ctx_segs[: len(ctx_segs) - k] + segs[k:]
        return segs

    def item_exists(self, segs, ctx: Module):
        """Resolve an absolute path to an item or module.

        Returns (found: bool, is_pub: bool | None, kind: str | None).
        """
        segs = self.absolutize(segs, ctx)
        if not segs or segs[0] not in self.crates:
            return True, None, "extern"   # std / unknown extern crate: skip
        if len(segs) == 1:
            return True, True, "crate"
        parent = self.lookup_module(segs[:-1])
        leaf = segs[-1]
        if parent is None:
            # maybe segs[:-1] ends at an ITEM (Enum::Variant, Type::assoc)
            gp = self.lookup_module(segs[:-2]) if len(segs) >= 3 else None
            if gp is not None:
                owner = segs[-2]
                return self.assoc_exists(gp, owner, leaf)
            return False, None, None
        hit = self.find_item(parent, leaf)
        if hit is not None:
            return True, hit[1], hit[0]
        # leaf may itself be a module
        if self.lookup_module(segs) is not None:
            return True, True, "module"
        # associated path one level up: parent module has item segs[-2]?
        return False, None, None

    def find_item(self, mod: Module, name: str, depth: int = 0):
        """Find item `name` in module. Returns (kind, is_pub) or None."""
        if name in mod.fns:
            return "fn", mod.fns[name].is_pub
        if name in mod.structs:
            return "struct", mod.structs[name].is_pub
        if name in mod.enums:
            return "enum", mod.enums[name].is_pub
        if name in mod.traits:
            return "trait", mod.traits[name].is_pub
        if name in mod.consts:
            return "const", mod.consts[name]
        if name in mod.types:
            return "type", mod.types[name]
        if name in mod.macros:
            return "macro", True
        if name in mod.submodules:
            return "module", True
        if name in mod.reexports and depth < 8:
            tgt = self.absolutize(mod.reexports[name], mod)
            if tgt and tgt[0] in self.crates:
                parent = self.lookup_module(tgt[:-1])
                if parent is not None:
                    inner = self.find_item(parent, tgt[-1], depth + 1)
                    if inner is not None:
                        return inner
                    if self.lookup_module(tgt) is not None:
                        return "module", True
                # Enum::Variant re-export
                if len(tgt) >= 2:
                    gp = self.lookup_module(tgt[:-2])
                    if gp is not None:
                        ok, pub, kind = self.assoc_exists(gp, tgt[-2], tgt[-1])
                        if ok:
                            return kind or "assoc", pub if pub is not None else True
                return None
            return "extern", True
        for g in mod.glob_reexports:
            if depth >= 8:
                break
            tgt = self.absolutize(g, mod)
            if tgt and tgt[0] in self.crates:
                gm = self.lookup_module(tgt)
                if gm is not None and gm is not mod:
                    inner = self.find_item(gm, name, depth + 1)
                    if inner is not None:
                        return inner
        return None

    def assoc_exists(self, mod: Module, owner: str, leaf: str):
        """owner is a type in mod; does leaf exist as variant/assoc fn/const?"""
        # enum variant?
        target = mod
        kind_pub = None
        if owner in mod.open_types:
            return True, True, "macro-impl"
        if owner in mod.enums:
            e = mod.enums[owner]
            if leaf in e.variants:
                return True, e.is_pub, "variant"
            kind_pub = e.is_pub
        elif owner in mod.structs:
            kind_pub = mod.structs[owner].is_pub
        elif owner in mod.types:
            # alias target unknown (often a std container): opaque
            return True, True, "alias"
        elif owner in mod.traits:
            kind_pub = True
        elif owner in mod.reexports:
            tgt = self.absolutize(mod.reexports[owner], mod)
            if tgt and tgt[0] in self.crates:
                parent = self.lookup_module(tgt[:-1])
                if parent is not None:
                    return self.assoc_exists(parent, tgt[-1], leaf)
            return True, None, "extern"
        else:
            found = False
            for g in mod.glob_reexports:
                tgt = self.absolutize(g, mod)
                gm = self.lookup_module(tgt) if tgt and tgt[0] in self.crates else None
                if gm is not None:
                    ok, pub, kind = self.assoc_exists(gm, owner, leaf)
                    if ok:
                        return ok, pub, kind
                    found = True
            if not found:
                return True, None, "extern"   # unknown owner type: skip
        # associated fn / const / trait method on ANY impl block crate-wide
        for crate in self.crates.values():
            for m in iter_modules(crate):
                if owner in m.assoc and leaf in m.assoc[owner]:
                    return True, m.assoc[owner][leaf].is_pub, "assocfn"
        # trait method (incl. defaults) usable as Type::method
        for crate in self.crates.values():
            for m in iter_modules(crate):
                for t in m.traits.values():
                    if leaf in t.required or leaf in t.provided:
                        return True, True, "traitmethod"
        # derive-provided names (clone, default, fmt, eq, hash, from …)
        if leaf in DERIVED_OK:
            return True, True, "derived"
        return False, kind_pub, None


DERIVED_OK = {
    "clone", "default", "fmt", "eq", "ne", "hash", "from", "into",
    "from_str", "to_string", "partial_cmp", "cmp", "to_owned",
}


def iter_modules(mod: Module):
    yield mod
    for sub in mod.submodules.values():
        yield from iter_modules(sub)


# ---------------------------------------------------------------------------
# Per-file reference checks (run over the masked source of every file)
# ---------------------------------------------------------------------------

ABS_PATH_RE = re.compile(
    r"\b(crate|anytime_mb|anyhow|xla)((?:::" + IDENT + r")+)"
)
STRUCT_LIT_RE = re.compile(
    r"\b(" + IDENT + r"(?:::" + IDENT + r")*)\s*\{"
)

TYPE_ASSOC_RE = re.compile(
    r"\b([A-Z][A-Za-z0-9_]*)::(" + IDENT + r")\b"
)

# std / prelude type names whose associated items we cannot know
STD_TYPES = {
    "Vec", "String", "Box", "Arc", "Rc", "Cell", "RefCell", "Mutex",
    "RwLock", "Option", "Some", "None", "Result", "Ok", "Err", "HashMap",
    "HashSet", "BTreeMap", "BTreeSet", "VecDeque", "BinaryHeap", "Duration",
    "Instant", "SystemTime", "PathBuf", "Path", "OsString", "OsStr",
    "Ordering", "Reverse", "Wrapping", "Cow", "Barrier", "Condvar",
    "Self", "Default", "Clone", "Copy", "Debug", "Display", "Iterator",
    "IntoIterator", "From", "Into", "TryFrom", "TryInto", "AsRef", "AsMut",
    "Send", "Sync", "Sized", "Drop", "Fn", "FnMut", "FnOnce", "ToString",
    "PartialEq", "Eq", "PartialOrd", "Ord", "Hash", "Error", "Write",
    "Read", "BufRead", "BufReader", "BufWriter", "File", "OpenOptions",
    "Command", "Stdio", "Output", "ExitCode", "ExitStatus", "Child",
    "JoinHandle", "Builder", "Sender", "Receiver", "SyncSender",
    "AtomicUsize", "AtomicBool", "AtomicU64", "NonZeroUsize", "NonZeroU64",
    "Range", "RangeInclusive", "Bound", "Entry", "Layout", "TypeId",
    "PhantomData", "ManuallyDrop", "MaybeUninit", "Pin", "Poll", "Context",
    "Waker", "IpAddr", "SocketAddr", "TcpListener", "TcpStream", "UdpSocket",
    "UnsafeCell", "Once", "OnceLock", "LazyLock", "Weak", "CString", "CStr",
    "FromUtf8Error", "Utf8Error", "ParseIntError", "ParseFloatError",
    "TryRecvError", "RecvTimeoutError", "SendError", "RecvError",
    "IteratorItem", "Chars", "Lines", "SplitWhitespace", "Args",
}


def check_type_assoc(file: str, masked: str, ctxs, res: Resolver):
    """Check `Type::item` references where Type is an imported/local crate
    type: item must be a variant, associated fn/const, trait method, or a
    derive-provided name."""
    for m in TYPE_ASSOC_RE.finditer(masked):
        owner, leaf = m.group(1), m.group(2)
        if owner in STD_TYPES:
            continue
        # part of a longer path like a::B::c? preceding `::` means the
        # owner segment is qualified — the ABS_PATH pass covers those.
        if masked[: m.start()].rstrip().endswith("::"):
            continue
        if masked[max(0, m.start() - 2) : m.start()] == "::":
            continue
        resolved_any, found = False, False
        for ctx in ctxs:
            mod = owner_module(owner, ctx, res)
            if mod is None:
                continue
            resolved_any = True
            ok, _pub, _kind = res.assoc_exists(mod, owner, leaf)
            if ok:
                found = True
                break
        if resolved_any and not found:
            finding(file, line_of(masked, m.start()), "unknown-assoc",
                    f"{owner}::{leaf} — `{owner}` has no such variant, "
                    f"associated item, or trait method")


def owner_module(owner: str, ctx: Module, res: Resolver):
    """Module in which `owner` is DEFINED, or None when it isn't a crate
    type reachable from ctx (locally defined, imported, or glob-imported)."""
    if owner in ctx.structs or owner in ctx.enums or owner in ctx.traits \
            or owner in ctx.types:
        return ctx
    if owner in ctx.reexports:
        tgt = res.absolutize(ctx.reexports[owner], ctx)
        if tgt and tgt[0] in res.crates:
            parent = res.lookup_module(tgt[:-1])
            if parent is not None and (
                tgt[-1] in parent.structs or tgt[-1] in parent.enums
                or tgt[-1] in parent.traits or tgt[-1] in parent.types
            ):
                return parent
        return None
    for g in ctx.glob_reexports:
        tgt = res.absolutize(g, ctx)
        if tgt and tgt[0] in res.crates:
            gm = res.lookup_module(tgt)
            if gm is not None and gm is not ctx:
                hit = owner_module(owner, gm, res)
                if hit is not None:
                    return hit
    return None


# std/core method names seen on primitives, slices, iterators, and the
# common std containers — receivers a static checker cannot type.  A
# `.name(` call outside this set and outside every crate-defined method
# is either a typo'd method or a new std usage to whitelist here.
STD_METHODS = {
    "abs", "all", "and_then", "any", "as_bytes", "as_deref", "as_mut",
    "as_mut_slice", "as_ptr", "as_ref", "as_secs", "as_secs_f64",
    "as_slice", "as_str", "binary_search", "binary_search_by", "borrow",
    "borrow_mut", "bytes", "ceil", "chain", "chars", "checked_add",
    "checked_mul", "checked_sub", "chunks", "chunks_exact", "chunks_mut",
    "clamp", "clear", "clone", "clone_from", "cloned", "cmp", "collect",
    "concat", "contains", "contains_key", "copied", "copy_from_slice",
    "cos", "count", "dedup", "dedup_by_key", "display", "drain",
    "elapsed", "ends_with", "entry", "enumerate", "eq", "exists", "exp",
    "extend", "extend_from_slice", "extension", "fetch_add", "fetch_or",
    "file_name", "file_stem", "fill", "filter", "filter_map", "find",
    "find_map", "first", "flat_map", "flatten", "floor", "flush", "fold",
    "for_each", "fract", "get", "get_mut", "get_or_init",
    "get_or_insert_with", "hash", "hypot", "insert", "inspect", "into",
    "into_inner", "into_iter", "into_owned", "is_absolute",
    "is_ascii_alphabetic", "is_ascii_alphanumeric", "is_ascii_digit",
    "is_ascii_hexdigit", "is_char_boundary", "is_dir", "is_empty",
    "is_err", "is_file", "is_finite", "is_infinite", "is_nan", "is_none",
    "is_ok", "is_sign_negative", "is_sign_positive", "is_some",
    "is_some_and", "is_whitespace", "iter", "iter_mut", "join", "keys",
    "last", "len", "lines", "ln", "lock", "log2", "map", "map_err",
    "map_or", "map_or_else", "max", "max_by", "max_by_key", "min",
    "min_by", "min_by_key", "mul_add", "mul_f64", "ne", "next",
    "next_back", "next_if", "nth", "ok", "ok_or", "ok_or_else", "or",
    "or_else", "or_insert", "or_insert_with", "parent", "parse",
    "partial_cmp", "partition", "peek", "peekable", "pop", "pop_front",
    "position", "powf", "powi", "product", "push", "push_back",
    "push_str", "range", "read_line", "read_to_string", "recv",
    "recv_timeout", "rem_euclid", "remove", "repeat", "replace",
    "replacen", "resize", "resize_with", "retain", "rev", "reverse",
    "rotate_left", "rotate_right", "round", "rposition", "rsplit",
    "saturating_add", "saturating_mul", "saturating_sub", "scan", "send",
    "set", "set_extension", "signum", "sin", "skip", "skip_while",
    "sort", "sort_by", "sort_by_key", "sort_unstable",
    "sort_unstable_by", "sort_unstable_by_key", "spawn", "split",
    "split_at", "split_at_mut", "split_first", "split_last", "split_off",
    "split_once", "split_terminator", "split_whitespace", "sqrt",
    "starts_with", "step_by", "store", "strip_prefix", "strip_suffix",
    "sum", "swap", "swap_remove", "take", "take_while", "tan", "then",
    "then_some", "then_with", "to_ascii_lowercase", "to_bits",
    "to_digit", "to_le_bytes", "to_lowercase", "to_owned",
    "to_path_buf", "to_str", "to_string", "to_string_lossy",
    "to_uppercase", "to_vec", "total_cmp", "trim", "trim_end",
    "trim_end_matches", "trim_start", "trim_start_matches", "trunc",
    "truncate", "try_fold", "try_for_each", "try_into", "unwrap",
    "unwrap_err", "unwrap_or", "unwrap_or_default", "unwrap_or_else",
    "unzip", "values", "values_mut", "wait", "wait_timeout", "windows",
    "with", "with_capacity", "wrapping_add", "wrapping_mul",
    "wrapping_neg", "wrapping_sub", "write_all", "write_fmt", "zip",
    "expect", "expect_err",
}

DOT_CALL_RE = re.compile(r"\.([a-z_][a-z0-9_]*)\s*(?:::<[^(]*>\s*)?\(")

BARE_CALL_RE = re.compile(r"(^|[^:.\w])([a-z_][a-z0-9_]*)\s*\(", re.M)


def check_call_arity(file: str, masked: str, ctxs, res: Resolver,
                     macro_fn_names: set):
    """Arity-check calls to crate FREE functions reachable as a bare
    identifier (local fn or single-item import).  Methods and macro-
    generated fns are out of scope; calls whose argument list contains a
    closure `|` are skipped (commas inside closure params defeat the
    depth-aware splitter)."""
    for m in BARE_CALL_RE.finditer(masked):
        name = m.group(2)
        if name in macro_fn_names:
            continue
        pre = masked[: m.start() + len(m.group(1))].rstrip()
        if pre.endswith(("fn", "impl", "trait", "mod", "use", "let", "mut",
                         "if", "while", "match", "for", "in", "move")):
            continue
        target = None
        for ctx in ctxs:
            if name in ctx.fns:
                target = ctx.fns[name]
                break
            if name in ctx.reexports:
                tgt = res.absolutize(ctx.reexports[name], ctx)
                if tgt and tgt[0] in res.crates:
                    parent = res.lookup_module(tgt[:-1])
                    if parent is not None and tgt[-1] in parent.fns:
                        target = parent.fns[tgt[-1]]
                break
        if target is None or target.has_self:
            continue
        open_idx = masked.index("(", m.end() - 1)
        depth, q = 0, open_idx
        while q < len(masked):
            if masked[q] == "(":
                depth += 1
            elif masked[q] == ")":
                depth -= 1
                if depth == 0:
                    break
            q += 1
        args_src = masked[open_idx + 1 : q]
        if "|" in args_src:
            continue
        n_args = len(split_top_commas(args_src))
        if n_args != target.arity:
            finding(file, line_of(masked, m.start() + len(m.group(1))),
                    "bad-arity",
                    f"{name}() called with {n_args} arg(s), defined with "
                    f"{target.arity} (at {target.line})")


def check_dot_calls(file: str, masked: str, known_methods: set):
    """Flag `.name(` calls where `name` is neither a crate-defined method
    (impl blocks, traits, macro-generated impls) nor a known std method.
    Receiver types are not inferred, so this is a NAME-existence check
    only — it catches renamed/typo'd methods, not wrong receivers."""
    for m in DOT_CALL_RE.finditer(masked):
        name = m.group(1)
        if name in known_methods or name in STD_METHODS:
            continue
        # tuple-ish numeric access `.0(` can't happen; closures stored in
        # fields are called as `(self.f)(..)` so a bare `.f(` here is a
        # genuine method call.
        finding(file, line_of(masked, m.start()), "unknown-method",
                f".{name}() is not defined by any crate impl/trait/macro "
                "and is not a known std method")


# keywords/idents that precede `{` but are never struct literals
NOT_STRUCT = {
    "if", "else", "match", "while", "loop", "for", "in", "fn", "impl",
    "trait", "mod", "struct", "enum", "union", "where", "unsafe", "move",
    "async", "dyn", "return", "break", "continue", "let", "pub", "use",
    "type", "const", "static", "ref", "mut", "as", "do", "try",
}


def check_refs(file: str, src: str, masked: str, ctxs, res: Resolver):
    """ctxs: all Modules whose source lives in `file` (outer + inline).
    A reference counts as resolved if it resolves in ANY of them — we
    cannot cheaply attribute byte ranges to inline modules, and a ref
    that resolves nowhere is broken in every context."""

    def resolve_any(segs):
        best = (False, None, None)
        for ctx in ctxs:
            ok, is_pub, kind = res.item_exists(segs, ctx)
            if ok and is_pub is not False:
                return ok, is_pub, kind, ctx
            if ok:
                best = (ok, is_pub, kind)
        return best[0], best[1], best[2], ctxs[0]

    # 1. absolute paths anywhere in the body
    for m in ABS_PATH_RE.finditer(masked):
        segs = [m.group(1)] + m.group(2).lstrip(":").split("::")
        segs = [s for s in segs if s]
        ok, is_pub, kind, ctx = resolve_any(segs)
        if not ok:
            finding(file, line_of(masked, m.start()), "unresolved-path",
                    "::".join(segs))
        elif is_pub is False and not same_crate(ctx, segs, res):
            finding(file, line_of(masked, m.start()), "private-item",
                    "::".join(segs) + " is not pub")

    # 2. use declarations
    for m in USE_RE.finditer(masked):
        spec = m.group(1)
        for prefix, leaves in expand_use_tree(spec):
            for leaf, _alias in leaves:
                if leaf == "*":
                    segs = prefix
                    if segs and segs[0] in ("std", "core", "alloc"):
                        continue
                    if segs and (segs[0] in res.crates or segs[0] in ("crate", "self", "super")):
                        if not any(
                            res.lookup_module(res.absolutize(segs, c)) is not None
                            for c in ctxs
                        ):
                            finding(file, line_of(masked, m.start()),
                                    "unresolved-use", "::".join(segs) + "::*")
                    continue
                segs = prefix + ([] if leaf == "self" else [leaf])
                if not segs or segs[0] in ("std", "core", "alloc"):
                    continue
                if segs[0] not in res.crates and segs[0] not in ("crate", "self", "super"):
                    continue
                ok, is_pub, kind, ctx = resolve_any(segs)
                if not ok:
                    finding(file, line_of(masked, m.start()), "unresolved-use",
                            "::".join(segs))
                elif is_pub is False and not same_crate(ctx, segs, res):
                    finding(file, line_of(masked, m.start()), "private-use",
                            "::".join(segs) + " is not pub")


def same_crate(ctx: Module, segs, res: Resolver) -> bool:
    abs_segs = res.absolutize(segs, ctx)
    return bool(abs_segs) and abs_segs[0] == ctx.path.split("::")[0]


def check_struct_literals(file: str, masked: str, ctxs, res: Resolver,
                          struct_index: dict):
    """Validate field names in `Path { a: .., b }` literals and patterns."""
    for m in STRUCT_LIT_RE.finditer(masked):
        path = m.group(1)
        last = path.split("::")[-1]
        if last in NOT_STRUCT or not last[0].isupper():
            continue
        pre = masked[: m.start()].rstrip()
        # `for x in Foo {` / `if cond {` style false positives: only accept
        # literals preceded by tokens that can introduce an expression or
        # pattern position.
        if pre.endswith(("=>", "=", "(", ",", "[", "{", "return", "else",
                         "box", ":", "&", ";", "|", "..")) is False and \
           not re.search(r"(?:Some|Ok|Err|vec!|push|insert|new)\s*\($", pre) and \
           not pre.endswith("&&") and not pre.endswith("||"):
            continue
        target = None
        for ctx in ctxs:
            target = resolve_struct(path, ctx, res, struct_index)
            if target is not None:
                break
        if target is None:
            continue
        s, owner_mod = target
        if s.is_tuple or not s.fields:
            continue
        open_idx = masked.index("{", m.end() - 1)
        end = matching_brace(masked, open_idx)
        body = masked[open_idx + 1 : end - 1]
        if "{" in body:
            # nested literals: only check the shallow field names
            body = blank_nested_braces(body)
        has_rest = re.search(r"\.\.", body) is not None
        named = set()
        for part in split_top_commas(body):
            part = part.strip()
            if part.startswith(".."):
                continue
            fm = re.match(r"(?:ref\s+)?(?:mut\s+)?(" + IDENT + r")\s*(?::|$|@)", part)
            if fm:
                named.add(fm.group(1))
        for f in named:
            if f not in s.fields:
                finding(file, line_of(masked, m.start()), "bad-field",
                        f"{path} has no field `{f}` "
                        f"(has: {', '.join(sorted(s.fields)) or 'none'})")
        if not has_rest and named and named != set(s.fields):
            missing = set(s.fields) - named
            if missing:
                finding(file, line_of(masked, m.start()), "missing-field",
                        f"{path} literal/pattern missing fields: "
                        f"{', '.join(sorted(missing))}")


def blank_nested_braces(body: str) -> str:
    out, depth = [], 0
    for ch in body:
        if ch == "{":
            depth += 1
            out.append(" ")
        elif ch == "}":
            depth = max(0, depth - 1)
            out.append(" ")
        else:
            out.append(ch if depth == 0 else (" " if ch != "\n" else "\n"))
    return "".join(out)


def resolve_struct(path: str, ctx: Module, res: Resolver, struct_index: dict):
    segs = path.split("::")
    if len(segs) == 1:
        name = segs[0]
        if name == "Self":
            return None
        # local module, then imports, then unique crate-wide match
        if name in ctx.structs:
            return ctx.structs[name], ctx
        if name in ctx.reexports:
            tgt = res.absolutize(ctx.reexports[name], ctx)
            if tgt and tgt[0] in res.crates:
                parent = res.lookup_module(tgt[:-1])
                if parent is not None and tgt[-1] in parent.structs:
                    return parent.structs[tgt[-1]], parent
            return None
        hits = struct_index.get(name, [])
        if len(hits) == 1:
            return hits[0]
        return None
    abs_segs = res.absolutize(segs, ctx)
    if abs_segs[0] not in res.crates:
        return None
    parent = res.lookup_module(abs_segs[:-1])
    if parent is not None and abs_segs[-1] in parent.structs:
        return parent.structs[abs_segs[-1]], parent
    return None


def check_trait_impls(res: Resolver):
    """Every `impl Trait for Type` must provide all required methods."""
    for cname, crate in res.crates.items():
        # the lib tree is registered under both `crate` and `anytime_mb`;
        # structural checks must only run once per physical tree
        if cname == "anytime_mb":
            continue
        for mod in iter_modules(crate):
            for trait_path, type_name, methods, line in mod.trait_impls:
                t = find_trait(res, mod, trait_path)
                if t is None:
                    continue
                missing = [r for r in t.required
                           if r not in methods and r not in t.provided]
                if missing:
                    finding(mod.file, line, "missing-trait-method",
                            f"impl {trait_path} for {type_name} missing "
                            f"required method(s): {', '.join(missing)}")


def find_trait(res: Resolver, ctx: Module, trait_path: str):
    segs = trait_path.split("::")
    name = segs[-1]
    if len(segs) == 1:
        if name in ctx.traits:
            return ctx.traits[name]
        if name in ctx.reexports:
            tgt = res.absolutize(ctx.reexports[name], ctx)
            if tgt and tgt[0] in res.crates:
                parent = res.lookup_module(tgt[:-1])
                if parent is not None:
                    return parent.traits.get(tgt[-1])
            return None
        # std traits (Display, Iterator, …): skip
        return None
    abs_segs = res.absolutize(segs, ctx)
    if abs_segs[0] not in res.crates:
        return None
    parent = res.lookup_module(abs_segs[:-1])
    return parent.traits.get(abs_segs[-1]) if parent else None


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def build_struct_index(res: Resolver) -> dict:
    idx = {}
    for crate in res.crates.values():
        for mod in iter_modules(crate):
            for s in mod.structs.values():
                idx.setdefault(s.name, []).append((s, mod))
    return idx


def target_files(repo: str):
    """(file, crate_root_module_name) pairs for standalone target crates."""
    out = []
    for d, aliases in (
        ("rust/tests", None), ("rust/benches", None), ("examples", None),
    ):
        full = os.path.join(repo, d)
        if not os.path.isdir(full):
            continue
        for fn in sorted(os.listdir(full)):
            if fn.endswith(".rs"):
                out.append(os.path.join(full, fn))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repo", default=".")
    args = ap.parse_args()
    repo = os.path.abspath(args.repo)

    crates = {}
    lib_root = os.path.join(repo, "rust/src/lib.rs")
    if not os.path.exists(lib_root):
        print(f"rustcheck: {lib_root} not found", file=sys.stderr)
        return 2
    crates["crate"] = load_crate(lib_root, "crate")
    # the same tree is visible to tests/benches/examples as `anytime_mb`
    crates["anytime_mb"] = load_crate(lib_root, "anytime_mb")
    for dep in ("anyhow", "xla"):
        droot = os.path.join(repo, f"rust/vendor/{dep}/src/lib.rs")
        if os.path.exists(droot):
            crates[dep] = load_crate(droot, dep)

    res = Resolver(crates)
    struct_index = build_struct_index(res)
    known_methods = set()
    macro_fn_names = set()
    for cr in crates.values():
        for m in iter_modules(cr):
            for fns in m.assoc.values():
                known_methods |= set(fns)
            for t in m.traits.values():
                known_methods |= set(t.required) | set(t.provided)
            known_methods |= m.macro_methods
            macro_fn_names |= m.macro_methods

    # 1. whole-crate structural checks
    check_trait_impls(res)

    # 2. per-file reference checks, lib crate: each FILE once, trying all
    #    module contexts (outer + inline mods) that live in it
    by_file = {}
    for mod in iter_modules(crates["crate"]):
        by_file.setdefault(mod.file, []).append(mod)
    for file, ctxs in by_file.items():
        with open(file, encoding="utf-8") as f:
            src = f.read()
        masked = mask_source(src)
        check_refs(file, src, masked, ctxs, res)
        check_struct_literals(file, masked, ctxs, res, struct_index)
        check_type_assoc(file, masked, ctxs, res)
        check_dot_calls(file, masked, known_methods)
        check_call_arity(file, masked, ctxs, res, macro_fn_names)

    # 3. binary crate main.rs + bin/, tests, benches, examples: standalone
    #    crates whose bodies reference `anytime_mb::…`
    standalone = [os.path.join(repo, "rust/src/main.rs"),
                  os.path.join(repo, "rust/src/bin/amb_lint.rs")]
    standalone += target_files(repo)
    # tests/common/mod.rs is pulled in via `mod common;`
    for file in standalone:
        if not os.path.exists(file):
            continue
        fake = load_crate(file, "test_crate")
        fake_by_file = {}
        for m in iter_modules(fake):
            fake_by_file.setdefault(m.file, []).append(m)
        for f_, ctxs in fake_by_file.items():
            with open(f_, encoding="utf-8") as fh:
                src = fh.read()
            masked = mask_source(src)
            check_refs(f_, src, masked, ctxs, res)
            check_struct_literals(f_, masked, ctxs, res, struct_index)
            check_type_assoc(f_, masked, ctxs, res)
            # methods defined by the standalone crate itself count too
            extra = set()
            for em in iter_modules(fake):
                for fns in em.assoc.values():
                    extra |= set(fns)
                for t in em.traits.values():
                    extra |= set(t.required) | set(t.provided)
                extra |= em.macro_methods
            check_dot_calls(f_, masked, known_methods | extra)
            check_call_arity(f_, masked, ctxs, res, macro_fn_names | extra)

    if not FINDINGS:
        print("rustcheck: clean")
        return 0
    FINDINGS.sort()
    for file, line, kind, msg in FINDINGS:
        rel = os.path.relpath(file, repo)
        print(f"{rel}:{line}: [{kind}] {msg}")
    print(f"rustcheck: {len(FINDINGS)} finding(s)")
    return 1


if __name__ == "__main__":
    sys.exit(main())
